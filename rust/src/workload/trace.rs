//! Trace recording / replay (CSV) — byte-identical workloads across
//! scheduler A/B runs and a substitute for the production request traces
//! the paper's authors used (DESIGN.md §Substitutions).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use super::{ArrivalProcess, Task, TaskClass, EMBED_DIM};

const HEADER: &str = "id,origin,class,model,user,service_secs,arrival_secs,\
deadline_secs,compute_tflops,memory_gb,payload_kb,embed";

/// Record every slot of `process` into a CSV trace file.
pub fn record<P: ArrivalProcess>(
    process: &mut P,
    slots: usize,
    slot_secs: f64,
    path: &Path,
) -> anyhow::Result<usize> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "{HEADER}")?;
    let mut n = 0;
    for slot in 0..slots {
        for t in process.slot_tasks(slot, slot_secs) {
            let embed = t
                .embed
                .iter()
                .map(|x| format!("{x:.5}"))
                .collect::<Vec<_>>()
                .join(";");
            writeln!(
                out,
                "{},{},{},{},{},{:.6},{:.6},{:.6},{:.4},{:.4},{:.4},{}",
                t.id,
                t.origin,
                t.class.name(),
                t.model,
                t.user,
                t.service_secs,
                t.arrival_secs,
                t.deadline_secs,
                t.compute_demand_tflops,
                t.memory_demand_gb,
                t.payload_kb,
                embed
            )?;
            n += 1;
        }
    }
    Ok(n)
}

/// Replays a recorded trace slot by slot.
pub struct TraceWorkload {
    n_regions: usize,
    /// Tasks sorted by arrival, partitioned lazily per slot.
    tasks: Vec<Task>,
    cursor: usize,
}

impl TraceWorkload {
    pub fn load(path: &Path, n_regions: usize) -> anyhow::Result<Self> {
        let file = std::fs::File::open(path)?;
        let mut lines = BufReader::new(file).lines();
        let header = lines.next().transpose()?.unwrap_or_default();
        anyhow::ensure!(header == HEADER, "unexpected trace header: {header}");
        let mut tasks = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            tasks.push(parse_line(&line).map_err(|e| {
                anyhow::anyhow!("trace line {}: {e}", lineno + 2)
            })?);
        }
        tasks.sort_by(|a, b| a.arrival_secs.partial_cmp(&b.arrival_secs).unwrap());
        Ok(TraceWorkload { n_regions, tasks, cursor: 0 })
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

fn parse_line(line: &str) -> Result<Task, String> {
    let cols: Vec<&str> = line.split(',').collect();
    if cols.len() != 12 {
        return Err(format!("expected 12 columns, got {}", cols.len()));
    }
    let f = |i: usize| -> Result<f64, String> {
        cols[i].parse().map_err(|_| format!("bad float in column {i}"))
    };
    let mut embed = [0f32; EMBED_DIM];
    for (k, part) in cols[11].split(';').enumerate() {
        if k >= EMBED_DIM {
            return Err("embedding too long".into());
        }
        embed[k] = part.parse().map_err(|_| "bad embed value".to_string())?;
    }
    Ok(Task {
        id: cols[0].parse().map_err(|_| "bad id")?,
        origin: cols[1].parse().map_err(|_| "bad origin")?,
        class: TaskClass::from_name(cols[2]).ok_or("bad class")?,
        model: cols[3].parse().map_err(|_| "bad model")?,
        user: cols[4].parse().map_err(|_| "bad user")?,
        service_secs: f(5)?,
        arrival_secs: f(6)?,
        deadline_secs: f(7)?,
        compute_demand_tflops: f(8)?,
        memory_demand_gb: f(9)?,
        payload_kb: f(10)?,
        embed,
    })
}

impl ArrivalProcess for TraceWorkload {
    fn n_regions(&self) -> usize {
        self.n_regions
    }

    fn expected_rate(&self, slot: usize) -> Vec<f64> {
        // Empirical per-region counts in the slot window (a replay's ground
        // truth is the trace itself). Slot duration is inferred at replay
        // time by slot_tasks; here we use 45 s, the system default.
        let slot_secs = 45.0;
        let lo = slot as f64 * slot_secs;
        let hi = lo + slot_secs;
        let mut rates = vec![0.0; self.n_regions];
        for t in &self.tasks {
            if t.arrival_secs >= lo && t.arrival_secs < hi {
                rates[t.origin] += 1.0;
            }
        }
        rates
    }

    fn slot_tasks(&mut self, slot: usize, slot_secs: f64) -> Vec<Task> {
        let hi = (slot + 1) as f64 * slot_secs;
        let mut out = Vec::new();
        while self.cursor < self.tasks.len() && self.tasks[self.cursor].arrival_secs < hi {
            out.push(self.tasks[self.cursor].clone());
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::DiurnalWorkload;

    #[test]
    fn record_and_replay_roundtrip() {
        let dir = std::env::temp_dir().join("torta_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");

        let mut gen = DiurnalWorkload::new(WorkloadConfig::default(), 3, 99);
        let n = record(&mut gen, 4, 45.0, &path).unwrap();
        assert!(n > 0);

        let mut replay = TraceWorkload::load(&path, 3).unwrap();
        assert_eq!(replay.len(), n);

        let mut gen2 = DiurnalWorkload::new(WorkloadConfig::default(), 3, 99);
        let mut total = 0;
        for slot in 0..4 {
            let want = gen2.slot_tasks(slot, 45.0);
            let got = replay.slot_tasks(slot, 45.0);
            assert_eq!(want.len(), got.len(), "slot {slot}");
            for (w, g) in want.iter().zip(got.iter()) {
                assert_eq!(w.id, g.id);
                assert_eq!(w.class, g.class);
                assert!((w.arrival_secs - g.arrival_secs).abs() < 1e-4);
                assert!((w.service_secs - g.service_secs).abs() < 1e-4);
            }
            total += got.len();
        }
        assert_eq!(total, n);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_header() {
        let dir = std::env::temp_dir().join("torta_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "nope\n1,2,3\n").unwrap();
        assert!(TraceWorkload::load(&path, 2).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_row() {
        assert!(parse_line("1,2,compute,0,0,bad,0,0,0,0,0,0;0;0;0;0;0;0;0").is_err());
        assert!(parse_line("short,row").is_err());
    }
}
