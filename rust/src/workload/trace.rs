//! Trace recording / replay (CSV) — bit-identical workloads across
//! scheduler A/B runs and a substitute for the production request traces
//! the paper's authors used (DESIGN.md §Substitutions).
//!
//! Floats are serialized with Rust's shortest round-trip formatting
//! (`{:?}`), so record → replay reproduces every `f64`/`f32` field
//! bit-for-bit (regression-tested here and in
//! `rust/tests/scenario_equivalence.rs`). Replay is a base
//! [`WorkloadSource`]; `trace:<path>` in a scenario spec builds one (see
//! `docs/SCENARIOS.md`).

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use super::{DemandForecast, Task, TaskClass, WorkloadSource, EMBED_DIM};

const HEADER: &str = "id,origin,class,model,user,service_secs,arrival_secs,\
deadline_secs,compute_tflops,memory_gb,payload_kb,embed";

/// Record every slot of `process` into a CSV trace file.
pub fn record(
    process: &mut dyn WorkloadSource,
    slots: usize,
    slot_secs: f64,
    path: &Path,
) -> anyhow::Result<usize> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "{HEADER}")?;
    let mut n = 0;
    for slot in 0..slots {
        for t in process.slot_tasks(slot, slot_secs) {
            let embed = t
                .embed
                .iter()
                .map(|x| format!("{x:?}"))
                .collect::<Vec<_>>()
                .join(";");
            writeln!(
                out,
                "{},{},{},{},{},{:?},{:?},{:?},{:?},{:?},{:?},{}",
                t.id,
                t.origin,
                t.class.name(),
                t.model,
                t.user,
                t.service_secs,
                t.arrival_secs,
                t.deadline_secs,
                t.compute_demand_tflops,
                t.memory_demand_gb,
                t.payload_kb,
                embed
            )?;
            n += 1;
        }
    }
    Ok(n)
}

/// Replays a recorded trace slot by slot.
pub struct TraceReplay {
    n_regions: usize,
    /// Tasks sorted by arrival, partitioned lazily per slot.
    tasks: Vec<Task>,
    cursor: usize,
    /// Slot duration assumed by the forecast view (`rate_at` bins the
    /// trace into windows of this length); `slot_tasks` always uses the
    /// caller's actual slot length.
    slot_secs: f64,
}

/// Legacy name for [`TraceReplay`] (pre-scenario API).
pub type TraceWorkload = TraceReplay;

impl TraceReplay {
    pub fn load(path: &Path, n_regions: usize) -> anyhow::Result<Self> {
        let file = std::fs::File::open(path)?;
        let mut lines = BufReader::new(file).lines();
        let header = lines.next().transpose()?.unwrap_or_default();
        anyhow::ensure!(header == HEADER, "unexpected trace header: {header}");
        let mut tasks = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            tasks.push(parse_line(&line).map_err(|e| {
                anyhow::anyhow!("trace line {}: {e}", lineno + 2)
            })?);
        }
        tasks.sort_by(|a, b| a.arrival_secs.partial_cmp(&b.arrival_secs).unwrap());
        Ok(TraceReplay { n_regions, tasks, cursor: 0, slot_secs: 45.0 })
    }

    /// Override the slot duration the forecast view bins with (the system
    /// default is 45 s).
    pub fn with_slot_secs(mut self, slot_secs: f64) -> Self {
        self.slot_secs = slot_secs.max(1e-9);
        self
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

fn parse_line(line: &str) -> Result<Task, String> {
    let cols: Vec<&str> = line.split(',').collect();
    if cols.len() != 12 {
        return Err(format!("expected 12 columns, got {}", cols.len()));
    }
    let f = |i: usize| -> Result<f64, String> {
        cols[i].parse().map_err(|_| format!("bad float in column {i}"))
    };
    let mut embed = [0f32; EMBED_DIM];
    for (k, part) in cols[11].split(';').enumerate() {
        if k >= EMBED_DIM {
            return Err("embedding too long".into());
        }
        embed[k] = part.parse().map_err(|_| "bad embed value".to_string())?;
    }
    Ok(Task {
        id: cols[0].parse().map_err(|_| "bad id")?,
        origin: cols[1].parse().map_err(|_| "bad origin")?,
        class: TaskClass::from_name(cols[2]).ok_or("bad class")?,
        model: cols[3].parse().map_err(|_| "bad model")?,
        user: cols[4].parse().map_err(|_| "bad user")?,
        service_secs: f(5)?,
        arrival_secs: f(6)?,
        deadline_secs: f(7)?,
        compute_demand_tflops: f(8)?,
        memory_demand_gb: f(9)?,
        payload_kb: f(10)?,
        embed,
        // Traces predate the token-serving model and replay scalar
        // (annotation, when wanted, layers on via `serving::Tokenized`).
        prompt_tokens: 0,
        output_tokens: 0,
        slo: None,
    })
}

impl DemandForecast for TraceReplay {
    fn n_regions(&self) -> usize {
        self.n_regions
    }

    fn rate_at(&self, slot: usize) -> Vec<f64> {
        // Empirical per-region counts in the slot window (a replay's
        // ground truth is the trace itself). Tasks are arrival-sorted, so
        // the window is two binary searches, not a full scan — keeps
        // `rate_horizon` cheap on long traces.
        let lo = slot as f64 * self.slot_secs;
        let hi = lo + self.slot_secs;
        let start = self.tasks.partition_point(|t| t.arrival_secs < lo);
        let end = self.tasks.partition_point(|t| t.arrival_secs < hi);
        let mut rates = vec![0.0; self.n_regions];
        for t in &self.tasks[start..end] {
            rates[t.origin] += 1.0;
        }
        rates
    }
}

impl WorkloadSource for TraceReplay {
    fn slot_tasks(&mut self, slot: usize, slot_secs: f64) -> Vec<Task> {
        let hi = (slot + 1) as f64 * slot_secs;
        let mut out = Vec::new();
        while self.cursor < self.tasks.len() && self.tasks[self.cursor].arrival_secs < hi {
            out.push(self.tasks[self.cursor].clone());
            self.cursor += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;
    use crate::workload::Diurnal;

    #[test]
    fn record_and_replay_roundtrip_bit_identical() {
        let dir = std::env::temp_dir().join("torta_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");

        let mut gen = Diurnal::new(WorkloadConfig::default(), 3, 99);
        let n = record(&mut gen, 4, 45.0, &path).unwrap();
        assert!(n > 0);

        let mut replay = TraceReplay::load(&path, 3).unwrap();
        assert_eq!(replay.len(), n);

        let mut gen2 = Diurnal::new(WorkloadConfig::default(), 3, 99);
        let mut total = 0;
        for slot in 0..4 {
            let want = gen2.slot_tasks(slot, 45.0);
            let got = replay.slot_tasks(slot, 45.0);
            assert_eq!(want.len(), got.len(), "slot {slot}");
            for (w, g) in want.iter().zip(got.iter()) {
                assert_eq!(w.id, g.id);
                assert_eq!(w.origin, g.origin);
                assert_eq!(w.class, g.class);
                assert_eq!(w.model, g.model);
                assert_eq!(w.user, g.user);
                assert_eq!(w.service_secs.to_bits(), g.service_secs.to_bits());
                assert_eq!(w.arrival_secs.to_bits(), g.arrival_secs.to_bits());
                assert_eq!(w.deadline_secs.to_bits(), g.deadline_secs.to_bits());
                assert_eq!(
                    w.compute_demand_tflops.to_bits(),
                    g.compute_demand_tflops.to_bits()
                );
                assert_eq!(w.memory_demand_gb.to_bits(), g.memory_demand_gb.to_bits());
                assert_eq!(w.payload_kb.to_bits(), g.payload_kb.to_bits());
                for (we, ge) in w.embed.iter().zip(g.embed.iter()) {
                    assert_eq!(we.to_bits(), ge.to_bits());
                }
            }
            total += got.len();
        }
        assert_eq!(total, n);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn replay_forecast_counts_trace_arrivals() {
        let dir = std::env::temp_dir().join("torta_trace_test_rates");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        let mut gen = Diurnal::new(WorkloadConfig::default(), 3, 5);
        record(&mut gen, 3, 45.0, &path).unwrap();
        let mut replay = TraceReplay::load(&path, 3).unwrap();
        let rates = replay.rate_at(1);
        let _slot0 = replay.slot_tasks(0, 45.0);
        let tasks = replay.slot_tasks(1, 45.0);
        let mut counts = vec![0.0; 3];
        for t in &tasks {
            counts[t.origin] += 1.0;
        }
        assert_eq!(rates, counts);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_malformed_header() {
        let dir = std::env::temp_dir().join("torta_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "nope\n1,2,3\n").unwrap();
        assert!(TraceReplay::load(&path, 2).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_row() {
        assert!(parse_line("1,2,compute,0,0,bad,0,0,0,0,0,0;0;0;0;0;0;0;0").is_err());
        assert!(parse_line("short,row").is_err());
    }
}
