//! Action-stream redesign regression suite:
//!
//! * with migration disabled, the `ExecutionEngine`'s action-stream
//!   execution must be bit-identical to the pre-redesign positional
//!   `SlotPlan` execution (replicated here as the oracle, including this
//!   PR's two engine bugfixes: FIFO backlog re-offer and failed-target
//!   re-buffering) for all schedulers — decisions, drops, buffer
//!   contents, alloc matrices, task metrics and fleet end state;
//! * `Migrate` actions execute end-to-end: source reservation refunded,
//!   destination queued, cost metered into `RunMetrics`;
//! * TORTA emits migrations in a failure scenario once
//!   `torta.migrate_backlog_secs` is set;
//! * backlog re-offer is FIFO-stable by arrival (starvation regression);
//! * assignments to failed targets are re-buffered, not silently dropped
//!   with zero wait.

use torta::cluster::Fleet;
use torta::config::ExperimentConfig;
use torta::metrics::{RunMetrics, TaskRecord};
use torta::scheduler::{
    empirical_alloc, Action, ActionResult, Ctx, PendingView, Scheduler, SlotDecision,
};
use torta::sim::{topo_salt, Simulation, DROP_WAIT_SECS, MIGRATION_SECS};
use torta::workload::{DiurnalWorkload, FailureEvent, Task, WorkloadSource};

/// Per-slot execution fingerprint: every assignment decision in order
/// (`Some((region, server))` = admitted, `None` = admission-dropped),
/// buffer contents, expiry drops, and the alloc matrix bit pattern.
#[derive(Debug, PartialEq, Eq)]
struct SlotFp {
    assigns: Vec<(u64, Option<(usize, usize)>)>,
    buffered: Vec<u64>,
    expired: Vec<u64>,
    alloc_bits: Vec<u64>,
}

/// Stable fleet fingerprint (drain-independent state only).
fn fleet_fp(fleet: &Fleet, t: f64) -> Vec<(u64, u64, u64)> {
    let mut fp = Vec::new();
    for region in &fleet.regions {
        for s in &region.servers {
            fp.push((s.tasks_served, s.model_switches, s.backlog_secs(t).to_bits()));
        }
    }
    fp
}

fn test_cfg(name: &str, slots: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = name.into();
    cfg.slots = slots;
    cfg.torta.use_pjrt = false;
    cfg
}

/// The pre-redesign execution loop, replicated verbatim as the oracle:
/// offer FIFO-sorted backlog + arrivals, expire, `schedule()` (the compat
/// shim over the ported schedulers), then positional-tuple execution with
/// the legacy admission control.
fn run_oracle(
    cfg: &ExperimentConfig,
    slots: usize,
) -> (Vec<SlotFp>, RunMetrics, Vec<(u64, u64, u64)>) {
    let holder = Simulation::new(cfg.clone()).unwrap();
    let ctx = &holder.ctx;
    let mut fleet = holder.fleet.clone();
    let mut wl = DiurnalWorkload::new(
        cfg.workload.clone(),
        ctx.topo.n,
        cfg.seed ^ topo_salt(&cfg.topology),
    );
    let mut sched = torta::scheduler::build(&cfg.scheduler, ctx, cfg).unwrap();
    let mut metrics = RunMetrics::new(&cfg.scheduler, &cfg.topology);
    let mut buffered: Vec<Task> = Vec::new();
    let mut fps = Vec::with_capacity(slots);
    for slot in 0..slots {
        let now = slot as f64 * cfg.slot_secs;
        for region in &mut fleet.regions {
            for s in &mut region.servers {
                s.tick_state(now);
            }
        }
        let mut fp = SlotFp {
            assigns: Vec::new(),
            buffered: Vec::new(),
            expired: Vec::new(),
            alloc_bits: Vec::new(),
        };
        let mut tasks = std::mem::take(&mut buffered);
        tasks.sort_by(|a, b| {
            a.arrival_secs
                .partial_cmp(&b.arrival_secs)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        tasks.extend(wl.slot_tasks(slot, cfg.slot_secs));
        tasks.retain(|t| {
            if now > t.deadline_secs {
                metrics.record_task(&TaskRecord {
                    task_id: t.id,
                    origin: t.origin,
                    served_region: t.origin,
                    network_secs: 0.0,
                    wait_secs: now - t.arrival_secs,
                    compute_secs: 0.0,
                    met_deadline: false,
                    dropped: true,
                    slo_class: None,
                    ttft_secs: 0.0,
                    tpot_secs: 0.0,
                    slo_met: false,
                });
                fp.expired.push(t.id);
                false
            } else {
                true
            }
        });
        let plan = sched.schedule(ctx, &mut fleet, tasks, slot, now);
        fleet.invalidate_aggregates();
        for (task, region, server_idx) in plan.assignments {
            let reg = &mut fleet.regions[region];
            assert!(!reg.failed && server_idx < reg.servers.len(), "no failures here");
            let server = &mut reg.servers[server_idx];
            let projected_start = server.earliest_start(now.max(task.arrival_secs));
            let projected_finish = projected_start + server.effective_service_secs(&task);
            if projected_start - task.arrival_secs > DROP_WAIT_SECS
                || projected_finish > task.deadline_secs + task.service_secs
            {
                metrics.record_task(&TaskRecord {
                    task_id: task.id,
                    origin: task.origin,
                    served_region: region,
                    network_secs: 0.0,
                    wait_secs: projected_start - task.arrival_secs,
                    compute_secs: 0.0,
                    met_deadline: false,
                    dropped: true,
                    slo_class: None,
                    ttft_secs: 0.0,
                    tpot_secs: 0.0,
                    slo_met: false,
                });
                fp.assigns.push((task.id, None));
                continue;
            }
            let out = server.assign(&task, now);
            let net = ctx.topo.network_secs(task.origin, region, task.payload_kb);
            metrics.record_task(&TaskRecord {
                task_id: task.id,
                origin: task.origin,
                served_region: region,
                network_secs: net,
                wait_secs: out.wait_secs,
                compute_secs: out.service_secs,
                met_deadline: out.finish_secs + net <= task.deadline_secs,
                dropped: false,
                slo_class: None,
                ttft_secs: 0.0,
                tpot_secs: 0.0,
                slo_met: false,
            });
            fp.assigns.push((task.id, Some((region, server_idx))));
        }
        fp.buffered = plan.buffered.iter().map(|t| t.id).collect();
        fp.alloc_bits = plan.alloc.iter().map(|x| x.to_bits()).collect();
        buffered = plan.buffered;
        fps.push(fp);
    }
    let end = slots as f64 * cfg.slot_secs;
    let ffp = fleet_fp(&fleet, end);
    (fps, metrics, ffp)
}

/// The same scenario through the action-stream engine.
fn run_engine(
    cfg: &ExperimentConfig,
    slots: usize,
) -> (Vec<SlotFp>, RunMetrics, Vec<(u64, u64, u64)>) {
    let mut engine = Simulation::new(cfg.clone()).unwrap();
    let mut wl = DiurnalWorkload::new(
        cfg.workload.clone(),
        engine.ctx.topo.n,
        cfg.seed ^ topo_salt(&cfg.topology),
    );
    let mut sched = torta::scheduler::build(&cfg.scheduler, &engine.ctx, cfg).unwrap();
    let mut metrics = RunMetrics::new(&cfg.scheduler, &cfg.topology);
    let mut fps = Vec::with_capacity(slots);
    for slot in 0..slots {
        engine.step(slot, &mut wl, sched.as_mut(), &mut metrics);
        let out = engine.last_outcome().expect("outcome after step");
        let mut fp = SlotFp {
            assigns: Vec::new(),
            buffered: Vec::new(),
            expired: Vec::new(),
            alloc_bits: out.alloc.iter().map(|x| x.to_bits()).collect(),
        };
        for res in &out.results {
            match res {
                ActionResult::Assigned { task_id, region, server, .. } => {
                    fp.assigns.push((*task_id, Some((*region, *server))));
                }
                ActionResult::Dropped { task_id, .. } => fp.assigns.push((*task_id, None)),
                ActionResult::Buffered { task_id, .. } => fp.buffered.push(*task_id),
                ActionResult::Expired { task_id, .. } => fp.expired.push(*task_id),
                ActionResult::Rebuffered { .. } => {
                    panic!("rebuffer impossible without failures")
                }
                ActionResult::Migrated { .. } | ActionResult::MigrateRejected { .. } => {
                    panic!("migration disabled")
                }
                ActionResult::Powered { .. } => {}
            }
        }
        fps.push(fp);
    }
    let end = slots as f64 * cfg.slot_secs;
    let ffp = fleet_fp(&engine.fleet, end);
    (fps, metrics, ffp)
}

#[test]
fn action_stream_bit_identical_to_slotplan_execution() {
    for name in ["rr", "sdib", "skylb", "torta-native", "reactive"] {
        let slots = 8;
        let cfg = test_cfg(name, slots);
        assert!(cfg.torta.migrate_backlog_secs == 0.0, "migration must be off");
        let (fp_a, m_a, fleet_a) = run_oracle(&cfg, slots);
        let (fp_b, m_b, fleet_b) = run_engine(&cfg, slots);
        for (slot, (a, b)) in fp_a.iter().zip(fp_b.iter()).enumerate() {
            assert_eq!(a, b, "{name}: fingerprint diverged at slot {slot}");
        }
        assert_eq!(m_a.tasks_total, m_b.tasks_total, "{name}");
        assert_eq!(m_a.tasks_dropped, m_b.tasks_dropped, "{name}");
        assert_eq!(m_a.deadline_misses, m_b.deadline_misses, "{name}");
        assert_eq!(m_a.response.len(), m_b.response.len(), "{name}");
        assert_eq!(
            m_a.mean_response().to_bits(),
            m_b.mean_response().to_bits(),
            "{name}: response means diverge"
        );
        assert_eq!(
            m_a.waiting.mean().to_bits(),
            m_b.waiting.mean().to_bits(),
            "{name}: waiting means diverge"
        );
        assert_eq!(
            m_a.network.mean().to_bits(),
            m_b.network.mean().to_bits(),
            "{name}: network means diverge"
        );
        assert_eq!(fleet_a, fleet_b, "{name}: fleet end state diverged");
    }
}

// ---------------------------------------------------------------------------
// Migration execution mechanics (scripted, deterministic).
// ---------------------------------------------------------------------------

/// Slot 0: pile every task onto one server of region 0 (creates queued
/// reservations). Later slots: migrate the most recent pending
/// reservation to region 1 and buffer all new arrivals.
struct MigrationScript {
    r: usize,
    migrated: Vec<u64>,
}

impl Scheduler for MigrationScript {
    fn name(&self) -> &'static str {
        "migration-script"
    }

    fn decide(
        &mut self,
        _ctx: &Ctx,
        fleet: &mut Fleet,
        tasks: Vec<Task>,
        pending: &[PendingView],
        slot: usize,
        now: f64,
    ) -> SlotDecision {
        let mut actions: Vec<Action> = Vec::new();
        if slot == 0 {
            let server = fleet.regions[0]
                .servers
                .iter()
                .position(|s| s.accepting(now))
                .expect("region 0 has an accepting server");
            let assignments: Vec<(Task, usize, usize)> =
                tasks.into_iter().map(|t| (t, 0usize, server)).collect();
            let alloc = empirical_alloc(&assignments, self.r);
            for (task, region, sv) in assignments {
                actions.push(Action::Assign { task, region, server: sv });
            }
            return SlotDecision { actions, alloc };
        }
        if let Some(p) = pending.last() {
            let dest = fleet.regions[1]
                .servers
                .iter()
                .position(|s| s.accepting(now))
                .expect("region 1 has an accepting server");
            self.migrated.push(p.task_id);
            actions.push(Action::Migrate {
                task_id: p.task_id,
                from: (p.region, p.server),
                to: (1, dest),
            });
        }
        for task in tasks {
            actions.push(Action::Buffer { task });
        }
        SlotDecision { actions, alloc: empirical_alloc(&[], self.r) }
    }
}

#[test]
fn migrate_action_executes_and_meters_cost() {
    // Runs once per shard-pipeline width: the scripted cross-shard
    // migration (region 0 -> region 1) must execute and meter identically
    // through the sequential path (threads = 1) and the parallel fan-out.
    let mut per_width: Vec<(f64, u64, u64, f64)> = Vec::new();
    for threads in [1usize, 4] {
        let mut cfg = ExperimentConfig::default();
        cfg.slots = 2;
        cfg.workload.base_rate = 10.0;
        cfg.torta.migrate_backlog_secs = 1.0; // enables pending tracking
        cfg.torta.threads = threads;
        let mut engine = Simulation::new(cfg.clone()).unwrap();
        let mut wl = DiurnalWorkload::new(
            cfg.workload.clone(),
            engine.ctx.topo.n,
            cfg.seed ^ topo_salt(&cfg.topology),
        );
        let mut sched = MigrationScript { r: engine.ctx.topo.n, migrated: Vec::new() };
        let mut metrics = RunMetrics::new("migration-script", &cfg.topology);

        engine.step(0, &mut wl, &mut sched, &mut metrics);
        assert!(
            engine.pending_len() >= 1,
            "piling one server must leave queued-but-unstarted reservations"
        );

        engine.step(1, &mut wl, &mut sched, &mut metrics);
        let out = engine.last_outcome().unwrap().clone();
        let migrated: Vec<&ActionResult> = out
            .results
            .iter()
            .filter(|r| matches!(r, ActionResult::Migrated { .. }))
            .collect();
        assert_eq!(migrated.len(), 1, "the scripted migration must execute");
        assert_eq!(out.migrated, 1);
        assert!((out.migration_secs - MIGRATION_SECS).abs() < 1e-12);
        if let ActionResult::Migrated { task_id, from, to, .. } = migrated[0] {
            assert_eq!(*task_id, sched.migrated[0]);
            assert_eq!(from.0, 0);
            assert_eq!(to.0, 1);
        }

        engine.finish(&mut metrics);
        assert_eq!(metrics.migrations, 1);
        assert!((metrics.migration_secs - MIGRATION_SECS).abs() < 1e-12);
        assert!(metrics.operational_overhead > 0.0);
        // The migrated task is recorded exactly once, served in region 1.
        assert!(metrics.tasks_total > 0);
        per_width.push((
            metrics.mean_response(),
            metrics.tasks_total,
            metrics.migrations,
            metrics.power_cost_dollars,
        ));
    }
    let (a, b) = (&per_width[0], &per_width[1]);
    assert_eq!(a.0.to_bits(), b.0.to_bits(), "response mean diverged across widths");
    assert_eq!(a.1, b.1, "tasks_total diverged across widths");
    assert_eq!(a.2, b.2, "migration count diverged across widths");
    assert_eq!(a.3.to_bits(), b.3.to_bits(), "power dollars diverged across widths");
}

#[test]
fn torta_migrates_under_failure_pressure() {
    // Acceptance scenario: high load + the three wealthiest regions
    // failing mid-run. With `torta.migrate_backlog_secs` set, TORTA's
    // micro layer must rescue/rebalance at least one queued reservation,
    // and RunMetrics must report the metered cost. Run at shard-pipeline
    // widths 1 and 4: the failed-region rescue routes source -> dest
    // across shard boundaries, and its metering must be identical to the
    // sequential path bit-for-bit.
    let run = |threads: usize| {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = "torta-native".into();
        cfg.slots = 14;
        cfg.workload.base_rate = 240.0;
        cfg.torta.use_pjrt = false;
        cfg.torta.migrate_backlog_secs = 1.0;
        cfg.torta.threads = threads;
        let mut engine = Simulation::new(cfg.clone()).unwrap();
        let mut by_size: Vec<usize> = (0..engine.fleet.n_regions()).collect();
        by_size.sort_by_key(|&r| std::cmp::Reverse(engine.fleet.regions[r].servers.len()));
        let failures: Vec<FailureEvent> = by_size[..3]
            .iter()
            .map(|&region| FailureEvent { region, start_slot: 2, duration_slots: 6 })
            .collect();
        engine = engine.with_failures(failures);
        let mut wl = DiurnalWorkload::new(
            cfg.workload.clone(),
            engine.ctx.topo.n,
            cfg.seed ^ topo_salt(&cfg.topology),
        );
        let mut sched = torta::scheduler::build("torta-native", &engine.ctx, &cfg).unwrap();
        let m = engine.run(&mut wl, sched.as_mut());
        let end = cfg.slots as f64 * cfg.slot_secs;
        let ffp = fleet_fp(&engine.fleet, end);
        (m, ffp)
    };
    let (m, f1) = run(1);
    assert!(
        m.migrations >= 1,
        "failure scenario executed no migrations (pending never formed?)"
    );
    assert!(m.migration_secs >= MIGRATION_SECS);
    assert!(m.operational_overhead > 0.0);
    let (m4, f4) = run(4);
    assert_eq!(m.migrations, m4.migrations, "migration count diverged across widths");
    assert_eq!(
        m.migration_secs.to_bits(),
        m4.migration_secs.to_bits(),
        "migration metering diverged across widths"
    );
    assert_eq!(m.tasks_total, m4.tasks_total);
    assert_eq!(
        m.mean_response().to_bits(),
        m4.mean_response().to_bits(),
        "response mean diverged across widths"
    );
    assert_eq!(
        m.power_cost_dollars.to_bits(),
        m4.power_cost_dollars.to_bits(),
        "power dollars diverged across widths"
    );
    assert_eq!(
        m.operational_overhead.to_bits(),
        m4.operational_overhead.to_bits(),
        "operational overhead diverged across widths"
    );
    assert_eq!(f1, f4, "fleet end state diverged across widths");
}

// ---------------------------------------------------------------------------
// Backlog FIFO stability + failed-target re-buffering (engine bugfixes).
// ---------------------------------------------------------------------------

/// Buffers everything, in *reverse* offer order, and records what it was
/// offered — the engine's FIFO re-sort must undo the scrambling.
struct ReverseBufferProbe {
    offered: Vec<Vec<(u64, f64)>>,
}

impl Scheduler for ReverseBufferProbe {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn decide(
        &mut self,
        ctx: &Ctx,
        _fleet: &mut Fleet,
        tasks: Vec<Task>,
        _pending: &[PendingView],
        _slot: usize,
        _now: f64,
    ) -> SlotDecision {
        self.offered.push(tasks.iter().map(|t| (t.id, t.arrival_secs)).collect());
        let mut actions: Vec<Action> = Vec::new();
        for task in tasks.into_iter().rev() {
            actions.push(Action::Buffer { task });
        }
        SlotDecision { actions, alloc: empirical_alloc(&[], ctx.topo.n) }
    }
}

#[test]
fn backlog_reoffer_is_fifo_by_arrival_and_expiry_has_honest_wait() {
    let mut cfg = ExperimentConfig::default();
    cfg.slots = 6;
    cfg.workload.base_rate = 8.0;
    let mut engine = Simulation::new(cfg.clone()).unwrap();
    let mut wl = DiurnalWorkload::new(
        cfg.workload.clone(),
        engine.ctx.topo.n,
        cfg.seed ^ topo_salt(&cfg.topology),
    );
    let mut probe = ReverseBufferProbe { offered: Vec::new() };
    let mut metrics = RunMetrics::new("probe", &cfg.topology);
    let mut expired_waits: Vec<f64> = Vec::new();
    for slot in 0..cfg.slots {
        engine.step(slot, &mut wl, &mut probe, &mut metrics);
        for res in &engine.last_outcome().unwrap().results {
            if let ActionResult::Expired { wait_secs, .. } = res {
                expired_waits.push(*wait_secs);
            }
        }
    }
    // Starvation regression: despite the probe buffering in reverse order
    // every slot, the re-offered backlog prefix must be a contiguous,
    // arrival-sorted block ahead of the new arrivals.
    for slot in 1..cfg.slots {
        let now = slot as f64 * cfg.slot_secs;
        let offered = &probe.offered[slot];
        let backlog_len = offered.iter().take_while(|(_, a)| *a < now).count();
        assert!(backlog_len > 0, "slot {slot}: backlog vanished");
        for rest in &offered[backlog_len..] {
            assert!(rest.1 >= now, "slot {slot}: backlog not a contiguous prefix");
        }
        for w in offered[..backlog_len].windows(2) {
            assert!(
                w[0].1 < w[1].1 || (w[0].1 == w[1].1 && w[0].0 < w[1].0),
                "slot {slot}: backlog not FIFO by arrival: {:?} before {:?}",
                w[0],
                w[1]
            );
        }
    }
    // Buffered tasks eventually expire — with their honest waiting time,
    // never a silent zero.
    assert!(metrics.tasks_dropped > 0, "nothing expired in 6 slots");
    assert_eq!(expired_waits.len(), metrics.tasks_dropped as usize);
    assert!(expired_waits.iter().all(|&w| w > 0.0), "expiry wait must be honest");
}

/// Assigns every task to a (failed) fixed region, recording offers.
struct FailedTargeter {
    target: usize,
    offered: Vec<Vec<u64>>,
}

impl Scheduler for FailedTargeter {
    fn name(&self) -> &'static str {
        "failed-targeter"
    }

    fn decide(
        &mut self,
        ctx: &Ctx,
        _fleet: &mut Fleet,
        tasks: Vec<Task>,
        _pending: &[PendingView],
        _slot: usize,
        _now: f64,
    ) -> SlotDecision {
        self.offered.push(tasks.iter().map(|t| t.id).collect());
        let assignments: Vec<(Task, usize, usize)> =
            tasks.into_iter().map(|t| (t, self.target, 0usize)).collect();
        let alloc = empirical_alloc(&assignments, ctx.topo.n);
        let mut actions: Vec<Action> = Vec::new();
        for (task, region, server) in assignments {
            actions.push(Action::Assign { task, region, server });
        }
        SlotDecision { actions, alloc }
    }
}

#[test]
fn failed_target_assignments_are_rebuffered_not_lost() {
    let mut cfg = ExperimentConfig::default();
    cfg.slots = 3;
    cfg.workload.base_rate = 6.0;
    let mut engine = Simulation::new(cfg.clone()).unwrap();
    engine = engine.with_failures(vec![FailureEvent {
        region: 0,
        start_slot: 0,
        duration_slots: 3,
    }]);
    let mut wl = DiurnalWorkload::new(
        cfg.workload.clone(),
        engine.ctx.topo.n,
        cfg.seed ^ topo_salt(&cfg.topology),
    );
    let mut sched = FailedTargeter { target: 0, offered: Vec::new() };
    let mut metrics = RunMetrics::new("failed-targeter", &cfg.topology);

    engine.step(0, &mut wl, &mut sched, &mut metrics);
    let out0 = engine.last_outcome().unwrap().clone();
    let rebuffered = out0
        .results
        .iter()
        .filter(|r| matches!(r, ActionResult::Rebuffered { .. }))
        .count();
    assert_eq!(rebuffered, sched.offered[0].len(), "every assignment re-buffered");
    assert_eq!(metrics.tasks_dropped, 0, "slot 0 must drop nothing");
    assert_eq!(engine.backlog_len(), sched.offered[0].len());

    engine.step(1, &mut wl, &mut sched, &mut metrics);
    // Every slot-0 task that survived expiry was re-offered at slot 1.
    let out1 = engine.last_outcome().unwrap().clone();
    let expired1: Vec<u64> = out1
        .results
        .iter()
        .filter_map(|r| match r {
            ActionResult::Expired { task_id, wait_secs } => {
                assert!(*wait_secs > 0.0, "expiry wait must be honest");
                Some(*task_id)
            }
            _ => None,
        })
        .collect();
    for id in &sched.offered[0] {
        assert!(
            sched.offered[1].contains(id) || expired1.contains(id),
            "task {id} vanished without a drop record"
        );
    }
}
