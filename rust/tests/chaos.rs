//! Chaos-layer integration tests (docs/FAULTS.md): the three registry
//! chaos scenarios end-to-end for every suite scheduler, task retry /
//! recovery accounting, the health-aware vs quarantine-less TORTA A/B,
//! and the `with_failures` composition regression (scenario-provided
//! failure events and explicitly injected ones must BOTH apply).

use torta::config::ExperimentConfig;
use torta::faults::FaultProfile;
use torta::metrics::RunMetrics;
use torta::scenario::{Scenario, CHAOS_REGISTRY};
use torta::sim::{run_experiment, topo_salt, Simulation};
use torta::workload::FailureEvent;

const SCHEDULERS: [&str; 4] = ["torta", "skylb", "sdib", "rr"];
const SLOTS: usize = 28;

fn chaos_cfg(scheduler: &str, scenario: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = scheduler.into();
    cfg.slots = SLOTS;
    cfg.torta.use_pjrt = false; // hermetic: no artifact dependence
    cfg.scenario = Scenario::by_name(scenario).unwrap();
    cfg
}

/// Acceptance: all three chaos scenarios run end-to-end for all four
/// schedulers, with nonzero fault / retry / lost-work metering and an
/// availability strictly below 1.0 (every preset has a crash component,
/// and crash windows are longer than a slot, so the boundary sweep
/// always observes down servers).
#[test]
fn chaos_scenarios_end_to_end_all_schedulers() {
    for scenario in CHAOS_REGISTRY {
        for scheduler in SCHEDULERS {
            let cfg = chaos_cfg(scheduler, scenario);
            let m = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{scheduler}@{scenario} failed: {e}"));
            let label = format!("{scheduler}@{scenario}");
            assert!(m.tasks_total > 0, "{label}: empty run proves nothing");
            assert!(m.server_slots > 0, "{label}: fault sweep never ran");
            assert!(m.faults_injected > 0, "{label}: no fault ever fired");
            assert!(m.server_down_slots > 0, "{label}: no down server observed");
            let avail = m.availability();
            assert!(avail < 1.0, "{label}: availability must dip below 1.0");
            assert!(avail > 0.5, "{label}: availability {avail} implausibly low");
            assert!(m.task_retries > 0, "{label}: crashes never re-queued work");
            assert!(m.lost_work_secs > 0.0, "{label}: no partial progress lost");
            assert!(m.ttr.len() > 0, "{label}: no repair ever completed");
        }
    }
}

/// Chaos runs are reproducible run-to-run: the schedule is resolved up
/// front from `(profile, fleet shape, horizon, seed)` and every mutation
/// happens in the sequential boundary sweep.
#[test]
fn chaos_run_is_deterministic_across_runs() {
    let cfg = chaos_cfg("torta", "flaky-network");
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.tasks_total, b.tasks_total);
    assert_eq!(a.faults_injected, b.faults_injected);
    assert_eq!(a.task_retries, b.task_retries);
    assert_eq!(a.quarantine_events, b.quarantine_events);
    assert_eq!(a.lost_work_secs.to_bits(), b.lost_work_secs.to_bits());
    assert_eq!(a.response.mean().to_bits(), b.response.mean().to_bits());
    assert_eq!(a.network.mean().to_bits(), b.network.mean().to_bits());
}

/// Acceptance A/B: under a heavy straggler profile (10x service-time
/// inflation on 40% of the fleet), health-aware TORTA — EWMA health
/// scoring, quarantine, degraded-server rescue — must beat the
/// quarantine-less run (`health_aware: false`, the only knob changed;
/// the fault schedule itself is bit-identical) on mean response.
#[test]
fn health_aware_quarantine_beats_naive_under_stragglers() {
    let run = |health_aware: bool| {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = "torta".into();
        cfg.slots = 30;
        cfg.torta.use_pjrt = false;
        let mut sc = Scenario::by_name("diurnal").unwrap();
        sc.faults = Some(FaultProfile {
            straggler_mtbf_secs: 300.0,
            straggler_mttr_secs: 600.0,
            straggler_frac: 0.4,
            straggler_slowdown: 10.0,
            health_aware,
            ..FaultProfile::default()
        });
        cfg.scenario = sc;
        run_experiment(&cfg).unwrap()
    };
    let naive = run(false);
    let aware = run(true);
    assert_eq!(
        naive.quarantine_events, 0,
        "health_aware=false must never quarantine"
    );
    assert!(
        aware.quarantine_events > 0,
        "stragglers this severe must trip the health floor"
    );
    assert!(
        aware.response.mean() < naive.response.mean(),
        "health-aware TORTA must beat the quarantine-less baseline under \
         heavy stragglers: aware={} naive={}",
        aware.response.mean(),
        naive.response.mean()
    );
}

/// Conservation under chaos: generated == recorded (served + dropped) +
/// still-buffered, where the backlog includes the retry queue; `finish`
/// must drain the in-flight list. Also bounds total retries by the
/// per-task budget in aggregate.
#[test]
fn task_conservation_and_retry_budget_under_chaos() {
    for scenario in CHAOS_REGISTRY {
        let cfg = chaos_cfg("rr", scenario);
        let mut sim = Simulation::new(cfg.clone()).unwrap();
        let seed = cfg.seed ^ topo_salt(&sim.ctx.topo.name);
        let n = sim.ctx.topo.n;
        let mut wl = cfg
            .scenario
            .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
            .unwrap();
        let mut twin = cfg
            .scenario
            .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
            .unwrap();
        let mut generated = 0u64;
        for slot in 0..cfg.slots {
            generated += twin.slot_tasks(slot, cfg.slot_secs).len() as u64;
        }
        let mut sched = torta::scheduler::build(&cfg.scheduler, &sim.ctx, &cfg).unwrap();
        let m = sim.run(wl.as_mut(), sched.as_mut());
        assert_eq!(
            m.tasks_total + sim.backlog_len() as u64,
            generated,
            "{scenario}: conservation violated under chaos"
        );
        assert_eq!(sim.inflight_len(), 0, "{scenario}: finish left in-flight work");
        let budget = cfg.scenario.faults.as_ref().unwrap().retry_budget as u64;
        assert!(
            m.task_retries <= generated * budget,
            "{scenario}: {} retries exceed {} tasks x budget {}",
            m.task_retries,
            generated,
            budget
        );
    }
}

/// A zero retry budget means lost work is dropped outright: no retries,
/// no recoveries, strictly more drops than the same run ever re-queues.
#[test]
fn zero_retry_budget_drops_lost_work_outright() {
    let mut cfg = chaos_cfg("rr", "chaos-crash");
    cfg.scenario.faults.as_mut().unwrap().retry_budget = 0;
    let m = run_experiment(&cfg).unwrap();
    assert!(m.faults_injected > 0, "crash preset must fire");
    assert_eq!(m.task_retries, 0, "budget 0 must never re-queue");
    assert_eq!(m.recovered_tasks, 0, "nothing retried, nothing recovered");
    assert!(m.tasks_dropped > 0, "harvested work must be dropped instead");
}

/// Regression (docs/API.md): `with_failures` EXTENDS the scenario's own
/// failure events instead of replacing them, and `clear_failures` wipes
/// both sources. The pre-fix behavior silently discarded the
/// regional-failure scenario's darkened regions whenever a caller added
/// an explicit event.
#[test]
fn with_failures_composes_with_scenario_failures() {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = "rr".into();
    cfg.slots = 10;
    cfg.workload.base_rate = 10.0;
    cfg.scenario = Scenario::by_name("regional-failure").unwrap();

    // Step slots 0..4 and report which regions are dark at slot 3 (inside
    // the scenario's slot 2..8 failure window).
    let failed_at_slot_3 = |extra: Option<FailureEvent>| -> (Vec<usize>, usize) {
        let mut sim = Simulation::new(cfg.clone()).unwrap();
        if let Some(f) = extra {
            sim = sim.with_failures(vec![f]);
        }
        let seed = cfg.seed ^ topo_salt(&sim.ctx.topo.name);
        let n = sim.ctx.topo.n;
        let mut wl = cfg
            .scenario
            .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
            .unwrap();
        let mut sched = torta::scheduler::build(&cfg.scheduler, &sim.ctx, &cfg).unwrap();
        let mut metrics = RunMetrics::new("rr", "abilene");
        for slot in 0..4 {
            sim.step(slot, wl.as_mut(), sched.as_mut(), &mut metrics);
        }
        let failed: Vec<usize> = sim
            .fleet
            .regions
            .iter()
            .filter(|r| r.failed)
            .map(|r| r.id)
            .collect();
        (failed, n)
    };

    let (base, n) = failed_at_slot_3(None);
    assert_eq!(base.len(), 3, "regional-failure darkens 3 regions: {base:?}");

    let extra_region = (0..n)
        .find(|r| !base.contains(r))
        .expect("some region survives the scenario");
    let (composed, _) = failed_at_slot_3(Some(FailureEvent {
        region: extra_region,
        start_slot: 2,
        duration_slots: 6,
    }));
    let mut want = base.clone();
    want.push(extra_region);
    want.sort_unstable();
    let mut got = composed;
    got.sort_unstable();
    assert_eq!(
        got, want,
        "with_failures must EXTEND the scenario failure set, not replace it"
    );

    // clear_failures drops the scenario-provided events too.
    let mut sim = Simulation::new(cfg.clone()).unwrap().clear_failures();
    let seed = cfg.seed ^ topo_salt(&sim.ctx.topo.name);
    let mut wl = cfg
        .scenario
        .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
        .unwrap();
    let mut sched = torta::scheduler::build(&cfg.scheduler, &sim.ctx, &cfg).unwrap();
    let mut metrics = RunMetrics::new("rr", "abilene");
    for slot in 0..4 {
        sim.step(slot, wl.as_mut(), sched.as_mut(), &mut metrics);
    }
    assert!(
        sim.fleet.regions.iter().all(|r| !r.failed),
        "clear_failures must wipe scenario-provided events"
    );
}
