//! CLI smoke tests: drive the built `torta` binary end-to-end.

use std::process::Command;

fn torta() -> Command {
    Command::new(env!("CARGO_BIN_EXE_torta"))
}

#[test]
fn help_lists_commands() {
    let out = torta().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["simulate", "suite", "train", "milp", "trace", "serve", "daemon"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn train_produces_artifact_that_simulate_loads() {
    // The acceptance loop through the real binary: `train` writes a
    // NativePolicy artifact, `simulate --scheduler torta --policy <path>`
    // runs with it (tiny topology/horizon so tier-1 stays fast).
    let dir = std::env::temp_dir().join("torta_cli_train");
    std::fs::create_dir_all(&dir).unwrap();
    let out = torta()
        .args([
            "train",
            "--topology",
            "synthetic-4",
            "--scenario",
            "surge",
            "--slots",
            "4",
            "--episodes",
            "2",
            "--seed",
            "7",
            "--no-eval",
            "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("saved native policy artifact"), "got: {text}");
    let artifact = dir.join("policy_r4.native.json");
    assert!(artifact.exists(), "missing {artifact:?}");
    let out = torta()
        .args([
            "simulate",
            "--topology",
            "synthetic-4",
            "--scheduler",
            "torta",
            "--slots",
            "4",
            "--no-pjrt",
            "--policy",
            artifact.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    // A load failure would print a "native fallback" warning on stderr.
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(!stderr.contains("native fallback"), "policy did not load: {stderr}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("torta"));
    std::fs::remove_file(&artifact).ok();
}

#[test]
fn simulate_runs_and_prints_row() {
    let out = torta()
        .args(["simulate", "--scheduler", "rr", "--slots", "6", "--no-pjrt"])
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rr") && text.contains("LB="), "got: {text}");
}

#[test]
fn simulate_with_config_file() {
    let dir = std::env::temp_dir().join("torta_cli_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("c.toml");
    std::fs::write(&path, "scheduler = \"sdib\"\nslots = 4\n[torta]\nuse_pjrt = false\n").unwrap();
    let out = torta()
        .args(["simulate", "--config", path.to_str().unwrap(), "--scheduler", "sdib", "--slots", "4", "--no-pjrt"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("sdib"));
}

#[test]
fn milp_prints_scaling_table() {
    let out = torta().args(["milp", "--tasks", "4,6", "--budget", "1000000"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("tasks") && text.contains("nodes"));
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = torta().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn bad_scheduler_reports_error() {
    let out = torta()
        .args(["simulate", "--scheduler", "nope", "--slots", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown scheduler"));
}

#[test]
fn trace_records_csv() {
    let dir = std::env::temp_dir().join("torta_cli_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.csv");
    let out = torta()
        .args(["trace", "--slots", "3", "--out", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let content = std::fs::read_to_string(&path).unwrap();
    assert!(content.lines().count() > 10);
    std::fs::remove_file(&path).ok();
}
