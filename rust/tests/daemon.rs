//! Control-plane daemon integration tests (docs/DAEMON.md): a real
//! daemon on an ephemeral loopback port, driven over HTTP.
//!
//! The headline assertion is the determinism acceptance criterion: a
//! scripted request set submitted over the wire, then drained, must
//! produce the exact `run_to_json` document — bit-for-bit — of a
//! virtual-time engine run over the equivalent merged workload. The
//! tests pin the daemon in slot 0's event phase with a tiny time scale
//! (45 s slots stretched to ~12.5 wall hours), so every scripted
//! request is queued before any slot steps and the drain then runs the
//! whole horizon back-to-back — no wall-clock nondeterminism anywhere.

use torta::config::ExperimentConfig;
use torta::daemon::{Daemon, DaemonOpts};
use torta::report;
use torta::serving::SloClass;
use torta::sim::{run_setup, Simulation};
use torta::util::http::http_call;
use torta::util::json::Json;
use torta::workload::{external_task, IngestSource, IngestSpec, INGEST_ID_BASE};

fn test_cfg(slots: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.topology = "synthetic-4".into();
    cfg.scheduler = "rr".into();
    cfg.slots = slots;
    cfg.workload.base_rate = 4.0;
    cfg.torta.use_pjrt = false;
    cfg
}

/// Pin the serve loop in the event phase: one 45 s slot per 45000 wall
/// seconds, so nothing steps until the drain request.
fn paused_opts(queue_cap: usize) -> DaemonOpts {
    DaemonOpts { time_scale: 0.001, queue_cap }
}

/// Reference run: the virtual-time engine over the same base workload
/// with the scripted requests pushed up front, exactly as the daemon's
/// ingest path builds them (same ids, same deadline slack).
fn reference_json(cfg: &ExperimentConfig, specs: &[IngestSpec]) -> String {
    let mut sim = Simulation::new(cfg.clone()).unwrap();
    let setup = run_setup(cfg).unwrap();
    let workload = setup.workload(cfg).unwrap();
    let mut sched = setup.scheduler(cfg).unwrap();
    let mut ingest = IngestSource::new(workload);
    for (i, spec) in specs.iter().enumerate() {
        ingest.push(external_task(
            INGEST_ID_BASE + i as u64,
            spec,
            cfg.workload.deadline_slack,
        ));
    }
    let mut m = sim.run(&mut ingest, sched.as_mut());
    report::run_to_json(&mut m).to_string_pretty()
}

fn spec(
    origin: usize,
    arrival: f64,
    service: f64,
    slo: Option<SloClass>,
    prompt: u32,
    output: u32,
) -> IngestSpec {
    IngestSpec {
        origin,
        arrival_secs: arrival,
        service_secs: service,
        slo,
        prompt_tokens: prompt,
        output_tokens: output,
    }
}

fn submit_body(s: &IngestSpec) -> String {
    let mut j = Json::obj();
    j.set("origin", s.origin)
        .set("arrival_s", s.arrival_secs)
        .set("service_secs", s.service_secs)
        .set("prompt_tokens", s.prompt_tokens as u64)
        .set("output_tokens", s.output_tokens as u64);
    if let Some(c) = s.slo {
        j.set("slo", c.name());
    }
    j.to_string_pretty()
}

#[test]
fn daemon_end_to_end_matches_engine_bitwise() {
    let cfg = test_cfg(4);
    let daemon = Daemon::spawn(cfg.clone(), paused_opts(1024), "127.0.0.1:0").unwrap();
    let addr = daemon.local_addr().to_string();

    // Scripted request set: mixed origins, SLO classes and token counts,
    // explicit arrivals spread over the 4-slot horizon (0..180 s). The
    // first four go through the single endpoint, the last two as one
    // batch — ids are assigned in submission order either way.
    let specs = [
        spec(0, 10.0, 12.0, Some(SloClass::Interactive), 128, 64),
        spec(1, 40.0, 8.0, Some(SloClass::Standard), 256, 128),
        spec(2, 95.0, 20.0, Some(SloClass::Batch), 512, 512),
        spec(3, 50.0, 10.0, None, 0, 0),
        spec(0, 100.0, 6.0, Some(SloClass::Interactive), 64, 32),
        spec(1, 130.0, 15.0, Some(SloClass::Standard), 128, 256),
    ];
    for (i, s) in specs[..4].iter().enumerate() {
        let (status, body) =
            http_call(&addr, "POST", "/v1/requests", Some(&submit_body(s))).unwrap();
        assert_eq!(status, 202, "submit {i}: {body}");
        let j = Json::parse(&body).unwrap();
        let id = j.get("id").and_then(Json::as_f64).unwrap() as u64;
        assert_eq!(id, INGEST_ID_BASE + i as u64);
        assert_eq!(j.get("status").and_then(Json::as_str), Some("queued"));
    }
    let mut batch = Json::obj();
    let mut arr = Json::Arr(vec![]);
    for s in &specs[4..] {
        arr.push(Json::parse(&submit_body(s)).unwrap());
    }
    batch.set("requests", arr);
    let (status, body) =
        http_call(&addr, "POST", "/v1/requests/batch", Some(&batch.to_string_pretty())).unwrap();
    assert_eq!(status, 202, "batch: {body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("accepted").and_then(Json::as_f64), Some(2.0));
    assert_eq!(j.get("shed").and_then(Json::as_f64), Some(0.0));
    assert_eq!(j.get("ids").and_then(Json::as_arr).map(<[Json]>::len), Some(2));

    // State surface while paused in slot 0: nothing stepped yet, all six
    // requests queued in the ingest source.
    let (status, body) = http_call(&addr, "GET", "/v1/healthz", None).unwrap();
    assert_eq!(status, 200);
    let h = Json::parse(&body).unwrap();
    assert_eq!(h.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(h.get("slot").and_then(Json::as_f64), Some(0.0));
    assert_eq!(h.get("ingest_pending").and_then(Json::as_f64), Some(6.0));
    assert_eq!(h.get("tasks_total").and_then(Json::as_f64), Some(0.0));

    let (status, body) = http_call(&addr, "GET", "/v1/fleet", None).unwrap();
    assert_eq!(status, 200);
    let f = Json::parse(&body).unwrap();
    assert_eq!(f.get("topology").and_then(Json::as_str), Some("synthetic-4"));
    assert_eq!(f.get("regions").and_then(Json::as_arr).map(<[Json]>::len), Some(4));

    let (status, body) = http_call(&addr, "GET", "/v1/regions/0", None).unwrap();
    assert_eq!(status, 200);
    let r = Json::parse(&body).unwrap();
    assert!(!r.get("servers").and_then(Json::as_arr).unwrap().is_empty());

    let (status, body) = http_call(&addr, "GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    let m = Json::parse(&body).unwrap();
    assert_eq!(m.get("tasks_total").and_then(Json::as_f64), Some(0.0));

    // Drain: the remaining horizon runs back-to-back and the response is
    // the final results JSON — bit-for-bit what the virtual-time engine
    // produces over the same merged workload.
    let (status, drained) = http_call(&addr, "POST", "/v1/drain", None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(drained, reference_json(&cfg, &specs), "daemon vs engine results JSON");

    let metrics = daemon.join().unwrap();
    let final_tasks =
        Json::parse(&drained).unwrap().get("tasks_total").and_then(Json::as_f64).unwrap();
    assert_eq!(metrics.tasks_total as f64, final_tasks);
}

#[test]
fn daemon_rejects_malformed_requests() {
    let cfg = test_cfg(2);
    let daemon = Daemon::spawn(cfg, paused_opts(16), "127.0.0.1:0").unwrap();
    let addr = daemon.local_addr().to_string();

    // Invalid JSON body.
    let (status, body) = http_call(&addr, "POST", "/v1/requests", Some("{nope")).unwrap();
    assert_eq!(status, 400, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some());
    // Unknown SLO class.
    let (status, _) =
        http_call(&addr, "POST", "/v1/requests", Some(r#"{"slo": "platinum"}"#)).unwrap();
    assert_eq!(status, 400);
    // Origin out of range for the 4-region fleet.
    let (status, body) =
        http_call(&addr, "POST", "/v1/requests", Some(r#"{"origin": 99}"#)).unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("origin"), "{body}");
    // Negative service time.
    let (status, _) =
        http_call(&addr, "POST", "/v1/requests", Some(r#"{"service_secs": -1}"#)).unwrap();
    assert_eq!(status, 400);
    // A batch with one bad entry admits nothing.
    let bad_batch = r#"{"requests": [{"origin": 0}, {"origin": 99}]}"#;
    let (status, body) =
        http_call(&addr, "POST", "/v1/requests/batch", Some(bad_batch)).unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("requests[1]"), "{body}");
    // Unknown endpoint and wrong method on a known one.
    let (status, _) = http_call(&addr, "GET", "/v1/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_call(&addr, "GET", "/v1/requests", None).unwrap();
    assert_eq!(status, 405);
    let (status, _) = http_call(&addr, "GET", "/v1/regions/zero", None).unwrap();
    assert_eq!(status, 400);
    let (status, _) = http_call(&addr, "GET", "/v1/regions/99", None).unwrap();
    assert_eq!(status, 404);

    // Nothing was admitted: the health endpoint still sees zero queued.
    let (_, body) = http_call(&addr, "GET", "/v1/healthz", None).unwrap();
    assert_eq!(
        Json::parse(&body).unwrap().get("ingest_pending").and_then(Json::as_f64),
        Some(0.0)
    );
    let (status, _) = http_call(&addr, "POST", "/v1/drain", None).unwrap();
    assert_eq!(status, 200);
    daemon.join().unwrap();
}

#[test]
fn overflow_sheds_to_batch_deterministically() {
    // queue_cap 0 forces every submission through the shed lane: the
    // request is still admitted, demoted to the batch SLO class. The
    // drained run must equal an engine run over batch-class tasks.
    let cfg = test_cfg(2);
    let daemon = Daemon::spawn(cfg.clone(), paused_opts(0), "127.0.0.1:0").unwrap();
    let addr = daemon.local_addr().to_string();

    let requested = [
        spec(0, 10.0, 12.0, Some(SloClass::Interactive), 128, 64),
        spec(1, 20.0, 8.0, None, 32, 16),
    ];
    for (i, s) in requested.iter().enumerate() {
        let (status, body) =
            http_call(&addr, "POST", "/v1/requests", Some(&submit_body(s))).unwrap();
        assert_eq!(status, 202, "shed submit {i}: {body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("status").and_then(Json::as_str), Some("shed-to-batch"));
    }
    let (status, drained) = http_call(&addr, "POST", "/v1/drain", None).unwrap();
    assert_eq!(status, 200);

    // What actually entered the run: the same specs with slo = batch.
    let effective: Vec<IngestSpec> = requested
        .iter()
        .map(|s| IngestSpec { slo: Some(SloClass::Batch), ..s.clone() })
        .collect();
    assert_eq!(drained, reference_json(&cfg, &effective));
    daemon.join().unwrap();
}

#[test]
fn metrics_stream_emits_slot_frames_and_done() {
    use std::io::{Read, Write};

    let cfg = test_cfg(2);
    let daemon = Daemon::spawn(cfg, paused_opts(16), "127.0.0.1:0").unwrap();
    let addr = daemon.local_addr().to_string();

    // Raw socket: http_call reads Content-Length responses only, and the
    // stream endpoint is chunked NDJSON held open across slots.
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    write!(
        stream,
        "GET /v1/metrics/stream HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )
    .unwrap();
    // Read the response head first: once it arrives, the subscription is
    // registered with the serve loop (the handler subscribes before
    // writing the head), so the drain below cannot race past it.
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        assert_eq!(stream.read(&mut byte).unwrap(), 1, "EOF before header end");
        head.push(byte[0]);
    }
    let head = String::from_utf8(head).unwrap();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("Transfer-Encoding: chunked"), "{head}");

    let (status, _) = http_call(&addr, "POST", "/v1/drain", None).unwrap();
    assert_eq!(status, 200);

    // Drain ran both slots; the stream got one frame per slot plus the
    // closing document, then the connection closed.
    let mut rest = String::new();
    stream.read_to_string(&mut rest).unwrap();
    assert!(rest.contains("\"slot\":0"), "{rest}");
    assert!(rest.contains("\"slot\":1"), "{rest}");
    assert!(rest.contains("\"done\":true"), "{rest}");
    assert!(rest.ends_with("0\r\n\r\n"), "unterminated chunks: {rest}");
    daemon.join().unwrap();
}
