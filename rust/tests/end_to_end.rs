//! Integration: every scheduler x topology runs end-to-end with invariants.

use torta::config::ExperimentConfig;
use torta::sim::run_experiment;
use torta::topology::TOPOLOGY_NAMES;

fn short_cfg(topology: &str, scheduler: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.topology = topology.into();
    cfg.scheduler = scheduler.into();
    cfg.slots = 24;
    cfg.torta.use_pjrt = false; // PJRT paths covered by runtime_roundtrip
    cfg
}

fn assert_run_sane(m: &torta::metrics::RunMetrics, label: &str) {
    assert!(m.tasks_total > 0, "{label}: no tasks");
    assert!(
        m.completion_rate() > 0.5,
        "{label}: completion {:.2}",
        m.completion_rate()
    );
    assert!(m.mean_response() > 0.0 && m.mean_response() < 300.0);
    assert!(m.mean_lb() > 0.0 && m.mean_lb() <= 1.0);
    assert!(m.power_cost_dollars > 0.0);
    assert!(m.operational_overhead >= 0.0);
}

/// Fast default coverage: every scheduler end-to-end on one topology.
/// The full scheduler x topology matrix is the `#[ignore]`d test below,
/// run by the full-suite CI job with `--include-ignored`.
#[test]
fn every_scheduler_smoke_on_abilene() {
    for sched in ["torta-native", "reactive", "skylb", "sdib", "rr"] {
        let mut cfg = short_cfg("abilene", sched);
        cfg.slots = 12;
        let m = run_experiment(&cfg)
            .unwrap_or_else(|e| panic!("{sched}@abilene failed: {e}"));
        assert_run_sane(&m, &format!("{sched}@abilene"));
    }
}

#[test]
#[ignore = "full scheduler x topology matrix; run with --include-ignored (CI full-suite job)"]
fn every_scheduler_on_every_topology() {
    for topo in TOPOLOGY_NAMES {
        for sched in ["torta-native", "reactive", "skylb", "sdib", "rr"] {
            let cfg = short_cfg(topo, sched);
            let m = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{sched}@{topo} failed: {e}"));
            assert_run_sane(&m, &format!("{sched}@{topo}"));
        }
    }
}

#[test]
fn torta_beats_rr_on_response_time() {
    // The robust headline ordering at modest horizons.
    let torta = run_experiment(&short_cfg("abilene", "torta-native")).unwrap();
    let rr = run_experiment(&short_cfg("abilene", "rr")).unwrap();
    assert!(
        torta.mean_response() < rr.mean_response(),
        "torta {:.2} !< rr {:.2}",
        torta.mean_response(),
        rr.mean_response()
    );
}

#[test]
fn torta_switching_cost_below_reactive() {
    // Theorem 3 mechanism at system level. 30 slots keeps tier-1 quick;
    // the 60-slot variant below runs with --include-ignored.
    let mut a = short_cfg("abilene", "torta-native");
    let mut b = short_cfg("abilene", "reactive");
    a.slots = 30;
    b.slots = 30;
    let torta = run_experiment(&a).unwrap();
    let reactive = run_experiment(&b).unwrap();
    assert!(
        torta.switching_cost_frob < reactive.switching_cost_frob,
        "torta {:.3} !< reactive {:.3}",
        torta.switching_cost_frob,
        reactive.switching_cost_frob
    );
}

#[test]
#[ignore = "long-horizon variant of the switching-cost ordering; run with --include-ignored"]
fn torta_switching_cost_below_reactive_long_horizon() {
    let mut a = short_cfg("abilene", "torta-native");
    let mut b = short_cfg("abilene", "reactive");
    a.slots = 60;
    b.slots = 60;
    let torta = run_experiment(&a).unwrap();
    let reactive = run_experiment(&b).unwrap();
    assert!(
        torta.switching_cost_frob < reactive.switching_cost_frob,
        "torta {:.3} !< reactive {:.3}",
        torta.switching_cost_frob,
        reactive.switching_cost_frob
    );
}

/// Fleet-scale suite target: the `fleet-256` registry scenario on the
/// synthetic-256 topology drives the R=256 shard pipeline in tier-1, so
/// fleet-width regressions (panics, nondeterminism across worker counts)
/// fail fast instead of only in the perf bench.
#[test]
fn fleet_256_scenario_runs_and_is_thread_invariant() {
    let mut cfg = short_cfg("synthetic-256", "torta-native");
    cfg.slots = 2; // two slots keep tier-1 quick; width is the point
    cfg.seed = 7;
    cfg.workload.base_rate = 4.0; // x4 rate-scale layer => 16/slot/region
    cfg.scenario = torta::scenario::Scenario::by_name("fleet-256").unwrap();
    cfg.torta.threads = 1;
    let a = run_experiment(&cfg).unwrap();
    assert!(a.tasks_total > 0, "fleet-256: no tasks");
    assert_eq!(a.scenario, "fleet-256");
    assert_eq!(a.lb_per_slot.len(), 2);
    // Determinism contract at full width: the sharded slot pipeline must
    // produce bit-identical metrics for any worker count (docs/PERF.md).
    cfg.torta.threads = 4;
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.tasks_total, b.tasks_total);
    assert_eq!(a.tasks_dropped, b.tasks_dropped);
    assert_eq!(a.mean_response().to_bits(), b.mean_response().to_bits());
    assert_eq!(a.power_cost_dollars.to_bits(), b.power_cost_dollars.to_bits());
    assert_eq!(a.switching_cost_frob.to_bits(), b.switching_cost_frob.to_bits());
    assert_eq!(a.mean_lb().to_bits(), b.mean_lb().to_bits());
}

#[test]
fn identical_seeds_are_bitwise_reproducible() {
    let a = run_experiment(&short_cfg("polska", "torta-native")).unwrap();
    let b = run_experiment(&short_cfg("polska", "torta-native")).unwrap();
    assert_eq!(a.tasks_total, b.tasks_total);
    assert_eq!(a.tasks_dropped, b.tasks_dropped);
    assert!((a.mean_response() - b.mean_response()).abs() < 1e-12);
    assert!((a.power_cost_dollars - b.power_cost_dollars).abs() < 1e-9);
    assert!((a.switching_cost_frob - b.switching_cost_frob).abs() < 1e-12);
}

#[test]
fn different_seeds_differ() {
    let mut cfg = short_cfg("abilene", "skylb");
    let a = run_experiment(&cfg).unwrap();
    cfg.seed = 1234;
    let b = run_experiment(&cfg).unwrap();
    assert_ne!(a.tasks_total, b.tasks_total);
}

#[test]
fn config_file_roundtrip_drives_run() {
    let dir = std::env::temp_dir().join("torta_e2e_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        "topology = \"polska\"\nscheduler = \"sdib\"\nslots = 8\n\
         [workload]\nbase_rate = 20.0\n[torta]\nuse_pjrt = false\n",
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(&path).unwrap();
    assert_eq!(cfg.topology, "polska");
    assert_eq!(cfg.slots, 8);
    let m = run_experiment(&cfg).unwrap();
    assert!(m.tasks_total > 0);
    std::fs::remove_file(&path).ok();
}
