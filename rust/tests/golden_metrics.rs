//! Golden-metrics regression: key `RunMetrics` fields for all four suite
//! schedulers x four registry scenarios at a short horizon, compared
//! BIT-FOR-BIT against a committed fixture — so future refactors diff
//! against bits, not vibes.
//!
//! Fixture: `rust/tests/golden/metrics.json`.
//!
//! Regenerate (after an *intentional* behaviour change) with:
//!
//! ```text
//! GOLDEN_REGEN=1 cargo test --test golden_metrics -- --nocapture
//! git add rust/tests/golden/metrics.json
//! ```
//!
//! Missing-fixture policy: outside `GOLDEN_REGEN=1` an absent fixture is
//! an ERROR in CI (`CI` env, set by GitHub Actions, or `GOLDEN_REQUIRE=1`)
//! — an unarmed guard silently validates nothing against history. Until
//! the fixture is committed, CI jobs therefore bootstrap it explicitly
//! (build-test uploads its copy as the `golden-metrics-fixture`
//! artifact so a maintainer can commit it), and every subsequent test
//! run in the workflow validates against those bootstrapped bits — the
//! `TORTA_THREADS` matrix legs prove the numbers are
//! thread-count-independent. A non-CI run on a checkout without the
//! fixture still bootstraps (with a loud warning) so a fresh clone's
//! suite is not red, and its very next run is armed. Comparisons are on
//! `f64::to_bits` of the shortest-round-trip JSON values, i.e. exact.

use std::path::PathBuf;

use torta::config::ExperimentConfig;
use torta::sim::run_experiment;
use torta::util::json::Json;

const SCHEDULERS: [&str; 4] = ["torta", "skylb", "sdib", "rr"];
/// Scenarios chosen so their event windows fire inside [`SLOTS`]:
/// regional-failure is dark over slots 2-8, flash-crowd ramps at 24, and
/// chaos-crash pins the fault-injection/retry path (docs/FAULTS.md) to
/// history too.
const SCENARIOS: [&str; 4] = ["diurnal", "regional-failure", "flash-crowd", "chaos-crash"];
const SLOTS: usize = 28;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/metrics.json")
}

fn run_one(scheduler: &str, scenario: &str) -> Json {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = scheduler.into();
    cfg.slots = SLOTS;
    cfg.torta.use_pjrt = false; // hermetic: no artifact dependence
    cfg.scenario = torta::scenario::Scenario::by_name(scenario).unwrap();
    let m = run_experiment(&cfg).unwrap_or_else(|e| panic!("{scheduler}@{scenario} failed: {e}"));
    let mut row = Json::obj();
    row.set("response_mean", m.response.mean())
        .set("waiting_mean", m.waiting.mean())
        .set("switching_cost_frob", m.switching_cost_frob)
        .set("power_cost_dollars", m.power_cost_dollars)
        .set("operational_overhead", m.operational_overhead)
        .set("migrations", m.migrations)
        .set("tasks_total", m.tasks_total)
        .set("tasks_dropped", m.tasks_dropped)
        // Chaos fields are all-zero (availability 1.0) on chaos-free
        // rows, so pinning them is free there and load-bearing on the
        // chaos-crash rows.
        .set("task_retries", m.task_retries)
        .set("lost_work_secs", m.lost_work_secs)
        .set("faults_injected", m.faults_injected)
        .set("availability", m.availability());
    row
}

fn run_all() -> Json {
    let mut root = Json::obj();
    for scenario in SCENARIOS {
        for scheduler in SCHEDULERS {
            root.set(&format!("{scheduler}@{scenario}"), run_one(scheduler, scenario));
        }
    }
    root
}

/// Token-mode-off oracle (docs/SERVING.md): a token registry scenario
/// with its serving spec stripped back to `None` must be bit-identical
/// to the legacy scalar run — the serving seam may not move a single bit
/// while it is off.
#[test]
fn token_mode_off_is_bit_identical_to_legacy_scalar_run() {
    for scheduler in SCHEDULERS {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = scheduler.into();
        cfg.slots = 12;
        cfg.torta.use_pjrt = false;
        let a = run_experiment(&cfg).unwrap();

        // tenant-mix is the diurnal baseline + a serving spec; stripping
        // the spec must recover the baseline exactly.
        let mut sc = torta::scenario::Scenario::by_name("tenant-mix").unwrap();
        sc.serving = None;
        sc.name = "diurnal".into();
        let mut cfg2 = cfg.clone();
        cfg2.scenario = sc;
        let b = run_experiment(&cfg2).unwrap();

        assert_eq!(a.tasks_total, b.tasks_total, "{scheduler}");
        assert_eq!(a.tasks_dropped, b.tasks_dropped, "{scheduler}");
        assert_eq!(a.mean_response().to_bits(), b.mean_response().to_bits(), "{scheduler}");
        assert_eq!(a.waiting.mean().to_bits(), b.waiting.mean().to_bits(), "{scheduler}");
        assert_eq!(a.power_cost_dollars.to_bits(), b.power_cost_dollars.to_bits(), "{scheduler}");
        assert_eq!(a.switching_cost_frob.to_bits(), b.switching_cost_frob.to_bits(), "{scheduler}");
        assert_eq!(b.token_tasks(), 0, "{scheduler}: scalar runs must meter no tokens");
    }
}

#[test]
fn metrics_match_golden_fixture() {
    let path = fixture_path();
    let regen = std::env::var("GOLDEN_REGEN").is_ok();
    if !regen && !path.exists() {
        // Fail loudly BEFORE burning simulation time: an absent fixture
        // outside GOLDEN_REGEN=1 means the guard is unarmed. "In CI" is a
        // truthy CI value — some local runners export CI=false/CI="",
        // which must keep the bootstrap-with-warning behavior.
        let truthy = |v: &str| !v.is_empty() && !v.eq_ignore_ascii_case("false") && v != "0";
        let strict = std::env::var("CI").map(|v| truthy(&v)).unwrap_or(false)
            || std::env::var("GOLDEN_REQUIRE").map(|v| truthy(&v)).unwrap_or(false);
        assert!(
            !strict,
            "golden fixture {path:?} is MISSING — the regression guard is unarmed.\n\
             Bootstrap and commit it:\n\
             \x20 GOLDEN_REGEN=1 cargo test --test golden_metrics -- --nocapture\n\
             \x20 git add rust/tests/golden/metrics.json\n\
             (CI's build-test job bootstraps one per run and uploads it as the\n\
             golden-metrics-fixture artifact; committing that file arms\n\
             validation against history instead of against the same workflow.)"
        );
        eprintln!(
            "golden_metrics: WARNING — fixture {path:?} missing; bootstrapping an \
             UNARMED fixture (commit it to arm history validation; CI refuses to \
             run unarmed)"
        );
    }
    let current = run_all();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, current.to_string_pretty()).unwrap();
        // Self-check: what we wrote parses back to the same values.
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, current, "fixture does not round-trip through JSON");
        // Self-check: a second run of one cell reproduces the fixture
        // bits, so bootstrap at least guards run-to-run determinism.
        let rerun = run_one("torta", "regional-failure");
        assert_eq!(
            current.get("torta@regional-failure"),
            Some(&rerun),
            "torta@regional-failure is not deterministic across runs"
        );
        eprintln!(
            "golden_metrics: {} fixture {path:?} — UNARMED until committed \
             (CI uploads it as the golden-metrics-fixture artifact)",
            if regen { "regenerated" } else { "bootstrapped" }
        );
        return;
    }
    let want = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .unwrap_or_else(|e| panic!("fixture {path:?} unparseable: {e}"));
    let keys: Vec<String> = SCENARIOS
        .iter()
        .flat_map(|sc| SCHEDULERS.iter().map(move |s| format!("{s}@{sc}")))
        .collect();
    for key in &keys {
        let got = current.get(key).unwrap_or_else(|| panic!("run missing key {key}"));
        let exp = want
            .get(key)
            .unwrap_or_else(|| panic!("fixture missing key {key} — regenerate (see header)"));
        for field in [
            "response_mean",
            "waiting_mean",
            "switching_cost_frob",
            "power_cost_dollars",
            "operational_overhead",
            "migrations",
            "tasks_total",
            "tasks_dropped",
            "task_retries",
            "lost_work_secs",
            "faults_injected",
            "availability",
        ] {
            let g = got.get(field).and_then(Json::as_f64);
            let e = exp.get(field).and_then(Json::as_f64);
            let (g, e) = match (g, e) {
                (Some(g), Some(e)) => (g, e),
                _ => panic!("{key}.{field}: missing in run ({g:?}) or fixture ({e:?})"),
            };
            assert!(
                g.to_bits() == e.to_bits(),
                "{key}.{field} drifted: got {g:?}, fixture {e:?}\n\
                 If this change is intentional, regenerate with:\n\
                 GOLDEN_REGEN=1 cargo test --test golden_metrics"
            );
        }
    }
}
