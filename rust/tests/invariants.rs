//! Property-based cross-module invariants: random configurations through
//! the full engine must preserve conservation, bounds, and determinism.

use torta::config::{ExperimentConfig, WorkloadConfig};
use torta::faults::{FaultProfile, FaultSchedule};
use torta::milp::{solve_bnb, solve_greedy, validate, AssignmentProblem};
use torta::ot;
use torta::scheduler::torta::macro_alloc::{normalize_rows, project_to_trust_region};
use torta::sim::Simulation;
use torta::util::prop;
use torta::workload::{
    Constant, DemandForecast, Diurnal, DiurnalWorkload, FlashCrowd, Mix, RateScale, Surge,
    SurgeWindow, WorkloadSource,
};

fn random_cfg(rng: &mut torta::util::rng::Rng) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.topology = ["abilene", "polska"][rng.below(2)].to_string();
    cfg.slots = rng.range(4, 10);
    cfg.seed = rng.next_u64();
    cfg.workload.base_rate = rng.uniform(5.0, 80.0);
    cfg.workload.diurnal_amp = rng.uniform(0.0, 0.9);
    cfg.workload.service_lo = rng.uniform(1.0, 8.0);
    cfg.workload.service_hi = cfg.workload.service_lo + rng.uniform(1.0, 20.0);
    cfg.workload.model_catalog = rng.range(1, 10);
    cfg.torta.use_pjrt = false;
    cfg.torta.smoothing = rng.f64();
    cfg.torta.eps_max = rng.uniform(0.05, 1.5);
    cfg
}

#[test]
fn task_conservation_under_random_configs() {
    prop::check(12, |rng, _size| {
        let cfg = random_cfg(rng);
        let sched_name =
            ["torta-native", "reactive", "skylb", "sdib", "rr"][rng.below(5)];
        let mut c = cfg.clone();
        c.scheduler = sched_name.to_string();
        let mut sim = Simulation::new(c.clone()).unwrap();
        let mut wl =
            DiurnalWorkload::new(c.workload.clone(), sim.ctx.topo.n, c.seed);
        let mut twin =
            DiurnalWorkload::new(c.workload.clone(), sim.ctx.topo.n, c.seed);
        let mut generated = 0u64;
        for slot in 0..c.slots {
            generated += twin.slot_tasks(slot, c.slot_secs).len() as u64;
        }
        let mut sched = torta::scheduler::build(sched_name, &sim.ctx, &c).unwrap();
        let m = sim.run(&mut wl, sched.as_mut());
        // served + dropped + still-buffered == generated
        assert_eq!(
            m.tasks_total + sim.backlog_len() as u64,
            generated,
            "{sched_name}: conservation violated"
        );
        // Bounds.
        if m.response.len() > 0 {
            assert!(m.mean_response() > 0.0);
            assert!(m.waiting.mean() >= 0.0);
        }
        assert!(m.mean_lb() > 0.0 && m.mean_lb() <= 1.0);
        assert!(m.power_cost_dollars >= 0.0);
        assert!(m.switching_cost_frob >= -1e-12);
    });
}

#[test]
fn milp_solutions_always_feasible_and_ordered() {
    prop::check(15, |rng, size| {
        let n = 2 + rng.below(size.min(10));
        let p = AssignmentProblem::generate(n, rng.next_u64());
        let exact = solve_bnb(&p, 5_000_000).expect("bnb");
        validate(&p, &exact).expect("bnb feasible");
        let greedy = solve_greedy(&p).expect("greedy");
        validate(&p, &greedy).expect("greedy feasible");
        if exact.optimal {
            assert!(
                exact.cost <= greedy.cost + 1e-9,
                "exact {} > greedy {}",
                exact.cost,
                greedy.cost
            );
        }
    });
}

// ---- OT / macro-allocator invariants (random R, costs, seeds) ----------

#[test]
fn sinkhorn_plan_marginals_match_within_tol() {
    prop::check(25, |rng, size| {
        let r = 2 + rng.below(size.min(16));
        let mu = prop::simplex(rng, r);
        let nu = prop::simplex(rng, r);
        let cost = prop::matrix(rng, r, r, 0.0, 1.0);
        let tol = 1e-6;
        let mut solver = ot::SinkhornSolver::new(&cost, r, 0.05, tol, 20_000);
        let plan = solver.solve(&mu, &nu).to_vec();
        assert!(
            solver.last_marginal_err <= tol,
            "R={r}: solver stopped at marginal err {} > tol {tol}",
            solver.last_marginal_err
        );
        for i in 0..r {
            let row: f64 = plan[i * r..(i + 1) * r].iter().sum();
            assert!((row - mu[i]).abs() <= tol, "R={r} row {i}: {row} vs {}", mu[i]);
        }
        for j in 0..r {
            // Column marginals are satisfied exactly by the final v-update
            // (up to rounding).
            let col: f64 = (0..r).map(|i| plan[i * r + j]).sum();
            assert!((col - nu[j]).abs() <= 1e-9, "R={r} col {j}: {col} vs {}", nu[j]);
        }
        assert!(plan.iter().all(|&x| x >= 0.0));
    });
}

#[test]
fn trust_region_projection_bounded_and_row_stochastic() {
    prop::check(50, |rng, size| {
        let r = 2 + rng.below(size.min(14));
        let mut anchor = prop::matrix(rng, r, r, 0.0, 1.0);
        normalize_rows(&mut anchor, r);
        let mut a = prop::matrix(rng, r, r, 0.0, 1.0);
        normalize_rows(&mut a, r);
        let eps = rng.uniform(0.02, 1.2);
        project_to_trust_region(&mut a, &anchor, eps, r);
        let dist = a
            .iter()
            .zip(&anchor)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt();
        assert!(dist <= eps + 1e-9, "R={r}: dist {dist} > eps {eps}");
        for i in 0..r {
            let row = &a[i * r..(i + 1) * r];
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "R={r} row {i} sums {s} after projection");
            assert!(row.iter().all(|&x| x >= -1e-12));
        }
    });
}

#[test]
fn normalize_rows_is_idempotent() {
    prop::check(50, |rng, size| {
        let r = 1 + rng.below(size.min(14));
        let mut a = prop::matrix(rng, r, r, -0.4, 1.0);
        if rng.chance(0.3) {
            // Exercise the degenerate all-non-positive row path too.
            let i = rng.below(r);
            for x in &mut a[i * r..(i + 1) * r] {
                *x = if rng.chance(0.5) { 0.0 } else { -rng.f64() };
            }
        }
        normalize_rows(&mut a, r);
        let once = a.clone();
        normalize_rows(&mut a, r);
        for (x, y) in once.iter().zip(&a) {
            assert!((x - y).abs() <= 1e-12, "normalize_rows not idempotent: {x} vs {y}");
        }
        for i in 0..r {
            let s: f64 = a[i * r..(i + 1) * r].iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    });
}

// ---- Chaos-layer fault-schedule invariants (docs/FAULTS.md) -------------

#[test]
fn fault_schedule_deterministic_and_well_formed() {
    prop::check(20, |rng, _size| {
        let mut p = FaultProfile::crash();
        p.crash_mtbf_secs = rng.uniform(200.0, 3000.0);
        p.crash_mttr_secs = rng.uniform(30.0, 400.0);
        if rng.chance(0.5) {
            p.straggler_mtbf_secs = rng.uniform(300.0, 2000.0);
            p.straggler_mttr_secs = rng.uniform(60.0, 500.0);
            p.straggler_frac = rng.uniform(0.1, 0.9);
            p.straggler_slowdown = rng.uniform(1.5, 8.0);
        }
        if rng.chance(0.5) {
            p.link_mtbf_secs = rng.uniform(400.0, 2000.0);
            p.link_mttr_secs = rng.uniform(60.0, 400.0);
            p.link_factor = rng.uniform(2.0, 30.0);
        }
        if rng.chance(0.5) {
            p.brownout_frac = rng.uniform(0.2, 0.9);
            p.brownout_start_secs = rng.uniform(0.0, 500.0);
            p.brownout_duration_secs = rng.uniform(50.0, 600.0);
        }
        p.validate().expect("randomized profile stays valid");
        let shape: Vec<usize> = (0..(2 + rng.below(5))).map(|_| 1 + rng.below(6)).collect();
        let horizon = rng.uniform(400.0, 2000.0);
        let seed = rng.next_u64();

        // Pure in (profile, shape, horizon, seed): bit-equal on replay.
        let a = FaultSchedule::generate(&p, &shape, horizon, seed);
        let b = FaultSchedule::generate(&p, &shape, horizon, seed);
        assert_eq!(a, b, "same inputs must give bit-equal schedules");
        // A different seed moves the timeline (guarded: an empty schedule
        // is trivially equal under any seed).
        if a.crash_count() > 2 {
            let c = FaultSchedule::generate(&p, &shape, horizon, seed ^ 0x9e37_79b9);
            assert_ne!(a, c, "seed must drive the schedule");
        }

        // Shape match.
        assert_eq!(a.servers.len(), shape.len());
        for (region, &count) in a.servers.iter().zip(&shape) {
            assert_eq!(region.len(), count);
        }

        // Windows well-formed: positive length, sorted, strictly disjoint
        // after normalization; slowdown factors are inflations.
        for sf in a.servers.iter().flatten() {
            for w in &sf.crashes {
                assert!(w.start >= 0.0 && w.start < w.end, "crash window {w:?}");
            }
            for pair in sf.crashes.windows(2) {
                assert!(
                    pair[0].end < pair[1].start,
                    "repair windows must not overlap: {pair:?}"
                );
            }
            for w in &sf.slowdowns {
                assert!(w.start >= 0.0 && w.start < w.end, "slow window");
                assert!(w.factor >= 1.0, "slowdown is an inflation, got {}", w.factor);
            }
        }
        for lf in &a.links {
            assert!(lf.a < lf.b && lf.b < shape.len(), "link endpoints ordered");
            assert!(lf.window.start < lf.window.end && lf.factor > 1.0);
        }
    });
}

#[test]
fn brownout_always_spares_a_server() {
    prop::check(20, |rng, _size| {
        let n = 2 + rng.below(4);
        let region = rng.below(n);
        let p = FaultProfile {
            brownout_frac: rng.uniform(0.3, 1.0),
            brownout_start_secs: 100.0,
            brownout_duration_secs: 300.0,
            brownout_region: Some(region),
            ..FaultProfile::default()
        };
        let shape: Vec<usize> = (0..n).map(|_| 2 + rng.below(6)).collect();
        let sched = FaultSchedule::generate(&p, &shape, 1000.0, rng.next_u64());
        let hit = sched.servers[region].iter().filter(|sf| !sf.crashes.is_empty()).count();
        assert!(
            hit < shape[region],
            "brownout must spare at least one server in region {region} \
             ({hit}/{} hit)",
            shape[region]
        );
        assert!(hit > 0, "a frac >= 0.3 brownout of >= 2 servers must hit one");
        // Even a frac-1.0 request caps below the full region.
        for (r, servers) in sched.servers.iter().enumerate() {
            if r != region {
                assert!(servers.iter().all(|sf| sf.crashes.is_empty()));
            }
        }
    });
}

// ---- Workload-combinator invariants (random stacks and horizons) --------

#[test]
fn combinator_stacks_superpose_rates_over_base() {
    use torta::workload::combinators::{FlashCrowdShape, RateShape, WeeklyShape};
    use torta::workload::WeeklySeasonal;
    prop::check(16, |rng, size| {
        let n = 2 + rng.below(5);
        let seed = rng.next_u64();
        let reference = Diurnal::new(WorkloadConfig::default(), n, seed);
        let mut src: Box<dyn WorkloadSource> =
            Box::new(Diurnal::new(WorkloadConfig::default(), n, seed));
        // Mirror each layer's documented multiplicative shape with a
        // closure; the composed stack's rate must equal base * product.
        let mut layers: Vec<Box<dyn Fn(usize, usize) -> f64>> = Vec::new();
        let depth = 1 + rng.below(size.min(3));
        for _ in 0..depth {
            match rng.below(4) {
                0 => {
                    let f = rng.uniform(0.3, 3.0);
                    src = Box::new(RateScale::wrap(src, f));
                    layers.push(Box::new(move |_, _| f));
                }
                1 => {
                    let start_slot = rng.below(20);
                    let end_slot = start_slot + 1 + rng.below(15);
                    let factor = rng.uniform(1.1, 4.0);
                    let region = if rng.chance(0.5) { Some(rng.below(n)) } else { None };
                    src = Box::new(Surge::wrap(
                        src,
                        vec![SurgeWindow { start_slot, end_slot, factor, region }],
                    ));
                    layers.push(Box::new(move |slot, reg| {
                        let hit = slot >= start_slot
                            && slot < end_slot
                            && region.map_or(true, |r| r == reg);
                        if hit {
                            factor
                        } else {
                            1.0
                        }
                    }));
                }
                2 => {
                    let at = rng.below(12);
                    let ramp = 1 + rng.below(3);
                    let hold = 1 + rng.below(4);
                    let decay = 1 + rng.below(4);
                    let factor = rng.uniform(1.5, 5.0);
                    let region = if rng.chance(0.5) { Some(rng.below(n)) } else { None };
                    src = Box::new(FlashCrowd::wrap(src, at, ramp, hold, decay, factor, region));
                    let shape = FlashCrowdShape { at, ramp, hold, decay, factor, region };
                    layers.push(Box::new(move |slot, reg| shape.factor(slot, reg)));
                }
                _ => {
                    let day_slots = 2 + rng.below(6);
                    let weekend_factor = rng.uniform(0.2, 0.9);
                    src = Box::new(WeeklySeasonal::wrap(src, day_slots, weekend_factor));
                    let shape = WeeklyShape { day_slots, weekend_factor };
                    layers.push(Box::new(move |slot, reg| shape.factor(slot, reg)));
                }
            }
        }
        for slot in [0usize, 3, 11, 26] {
            let got = src.rate_at(slot);
            let base_rates = reference.rate_at(slot);
            for reg in 0..n {
                let want: f64 =
                    base_rates[reg] * layers.iter().map(|f| f(slot, reg)).product::<f64>();
                assert!(
                    (got[reg] - want).abs() <= 1e-9 * want.max(1.0),
                    "slot {slot} region {reg}: {} vs {want}",
                    got[reg]
                );
            }
        }
        // Horizon contract: rate_horizon == slotwise rate_at, bitwise.
        let slot = rng.below(30);
        let horizon = 1 + rng.below(8);
        let h = src.rate_horizon(slot, horizon);
        assert_eq!(h.len(), horizon);
        for (k, rates) in h.iter().enumerate() {
            assert_eq!(rates, &src.rate_at(slot + k), "horizon slot {}", slot + k);
        }
    });
}

#[test]
fn mix_superposes_member_rates() {
    prop::check(16, |rng, _size| {
        let n = 2 + rng.below(4);
        let k = 2 + rng.below(3);
        let mut members: Vec<Box<dyn WorkloadSource>> = Vec::new();
        let mut twins: Vec<Box<dyn WorkloadSource>> = Vec::new();
        for _ in 0..k {
            let seed = rng.next_u64();
            if rng.chance(0.5) {
                let rate = rng.uniform(2.0, 30.0);
                members.push(Box::new(Constant::new(WorkloadConfig::default(), n, seed, rate)));
                twins.push(Box::new(Constant::new(WorkloadConfig::default(), n, seed, rate)));
            } else {
                members.push(Box::new(Diurnal::new(WorkloadConfig::default(), n, seed)));
                twins.push(Box::new(Diurnal::new(WorkloadConfig::default(), n, seed)));
            }
        }
        let mix = Mix::new(members).unwrap();
        for slot in [0usize, 5, 17] {
            let got = mix.rate_at(slot);
            for reg in 0..n {
                let want: f64 = twins.iter().map(|t| t.rate_at(slot)[reg]).sum();
                assert!(
                    (got[reg] - want).abs() < 1e-9,
                    "slot {slot} region {reg}: {} vs {want}",
                    got[reg]
                );
            }
        }
        let slot = rng.below(20);
        let horizon = 1 + rng.below(6);
        for (kk, rates) in mix.rate_horizon(slot, horizon).iter().enumerate() {
            assert_eq!(rates, &mix.rate_at(slot + kk));
        }
    });
}

// ---- Token-serving sampler invariants (docs/SERVING.md) -----------------

#[test]
fn token_sampler_deterministic_salted_and_length_bounded() {
    use torta::serving::{ServingSpec, Tokenized};
    prop::check(16, |rng, _size| {
        let n = 2 + rng.below(4);
        let seed = rng.next_u64();
        let mk = |s: u64| {
            Tokenized::wrap(
                Diurnal::new(WorkloadConfig::default(), n, s),
                ServingSpec::default(),
                s,
            )
        };
        let (mut a, mut b) = (mk(seed), mk(seed));
        // The topology fold XORs a salt into the seed; the sampler must
        // follow it, not collapse every topology onto one token stream.
        let mut salted = mk(seed ^ 0x9e37_79b9);
        let mut salt_moved = false;
        for slot in 0..3 {
            let ta = a.slot_tasks(slot, 45.0);
            let tb = b.slot_tasks(slot, 45.0);
            let ts = salted.slot_tasks(slot, 45.0);
            assert_eq!(ta.len(), tb.len());
            for (x, y) in ta.iter().zip(&tb) {
                assert_eq!(
                    (x.prompt_tokens, x.output_tokens, x.slo),
                    (y.prompt_tokens, y.output_tokens, y.slo),
                    "same seed must replay the same annotations"
                );
            }
            for t in &ta {
                let class = t.slo.expect("every task annotated");
                let (plo, phi) = class.prompt_bounds();
                let (olo, ohi) = class.output_bounds();
                assert!((plo..=phi).contains(&t.prompt_tokens));
                assert!((olo..=ohi).contains(&t.output_tokens));
            }
            for (x, y) in ta.iter().zip(&ts) {
                if (x.prompt_tokens, x.output_tokens) != (y.prompt_tokens, y.output_tokens) {
                    salt_moved = true;
                }
            }
        }
        assert!(salt_moved, "a salted seed must perturb the token stream");
    });
}

#[test]
fn token_drift_multiplies_output_lengths_exactly() {
    use torta::serving::{ServingSpec, TokenDriftSpec, Tokenized};
    use torta::workload::combinators::TokenDrift;
    prop::check(12, |rng, _size| {
        let n = 2 + rng.below(3);
        let seed = rng.next_u64();
        let spec = TokenDriftSpec {
            at: rng.below(4),
            ramp: rng.below(4),
            factor: rng.uniform(1.2, 4.0),
        };
        let mk = || {
            Tokenized::wrap(
                Diurnal::new(WorkloadConfig::default(), n, seed),
                ServingSpec::default(),
                seed,
            )
        };
        let mut plain = mk();
        let mut drifted = TokenDrift::wrap(mk(), spec);
        for slot in 0..(spec.at + spec.ramp + 3) {
            let f = drifted.factor_at(slot);
            if slot < spec.at {
                assert!((f - 1.0).abs() < 1e-12, "no drift before `at`");
            }
            if slot >= spec.at + spec.ramp {
                assert!((f - spec.factor).abs() < 1e-12, "steady state holds `factor`");
            }
            let ta = plain.slot_tasks(slot, 45.0);
            let tb = drifted.slot_tasks(slot, 45.0);
            assert_eq!(ta.len(), tb.len(), "drift must not touch the arrival process");
            for (x, y) in ta.iter().zip(&tb) {
                assert_eq!(x.arrival_secs.to_bits(), y.arrival_secs.to_bits());
                assert_eq!(x.prompt_tokens, y.prompt_tokens, "prompts are untouched");
                let want = if f == 1.0 {
                    x.output_tokens
                } else {
                    ((x.output_tokens as f64 * f).round() as u32).max(1)
                };
                assert_eq!(y.output_tokens, want, "slot {slot} factor {f}");
            }
        }
    });
}

#[test]
fn token_slot_occupancy_never_exceeds_concurrency_bound() {
    use torta::cluster::{Server, ALL_GPUS};
    use torta::serving::{ServingSpec, Tokenized};
    prop::check(12, |rng, _size| {
        let gpu = ALL_GPUS[rng.below(ALL_GPUS.len())];
        let mut s = Server::new(0, 0, gpu, true);
        s.loaded_model = Some(0);
        s.set_lane_count(gpu.token_slots());
        let model = ServingSpec::default().model();
        let mut wl = Tokenized::wrap(
            Diurnal::new(WorkloadConfig::default(), 1, rng.next_u64()),
            ServingSpec::default(),
            rng.next_u64(),
        );
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        for slot in 0..3 {
            let now = slot as f64 * 45.0;
            for mut t in wl.slot_tasks(slot, 45.0) {
                t.model = 0; // keep switch stalls out of the occupancy picture
                let out = s.assign_serving(&t, now, &model);
                intervals.push((out.start_secs, out.finish_secs));
            }
        }
        let bound = gpu.token_slots();
        for &(start, _) in &intervals {
            let running = intervals.iter().filter(|&&(a, b)| a <= start && start < b).count();
            assert!(running <= bound, "{running} > {bound} concurrent requests on {gpu:?}");
        }
    });
}

#[test]
fn switching_cost_zero_for_constant_allocation() {
    // A scheduler that reports the same alloc every slot accrues zero
    // Frobenius switching cost regardless of workload randomness.
    prop::check(8, |rng, _| {
        let cfg = random_cfg(rng);
        let mut m = torta::metrics::RunMetrics::new("const", "x");
        let r = 4;
        let alloc = prop::simplex(rng, r * r);
        for _ in 0..cfg.slots {
            m.record_alloc(&alloc);
        }
        assert!(m.switching_cost_frob.abs() < 1e-12);
    });
}
