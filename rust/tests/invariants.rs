//! Property-based cross-module invariants: random configurations through
//! the full engine must preserve conservation, bounds, and determinism.

use torta::config::ExperimentConfig;
use torta::milp::{solve_bnb, solve_greedy, validate, AssignmentProblem};
use torta::sim::Simulation;
use torta::util::prop;
use torta::workload::{DiurnalWorkload, WorkloadSource};

fn random_cfg(rng: &mut torta::util::rng::Rng) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.topology = ["abilene", "polska"][rng.below(2)].to_string();
    cfg.slots = rng.range(4, 10);
    cfg.seed = rng.next_u64();
    cfg.workload.base_rate = rng.uniform(5.0, 80.0);
    cfg.workload.diurnal_amp = rng.uniform(0.0, 0.9);
    cfg.workload.service_lo = rng.uniform(1.0, 8.0);
    cfg.workload.service_hi = cfg.workload.service_lo + rng.uniform(1.0, 20.0);
    cfg.workload.model_catalog = rng.range(1, 10);
    cfg.torta.use_pjrt = false;
    cfg.torta.smoothing = rng.f64();
    cfg.torta.eps_max = rng.uniform(0.05, 1.5);
    cfg
}

#[test]
fn task_conservation_under_random_configs() {
    prop::check(12, |rng, _size| {
        let cfg = random_cfg(rng);
        let sched_name =
            ["torta-native", "reactive", "skylb", "sdib", "rr"][rng.below(5)];
        let mut c = cfg.clone();
        c.scheduler = sched_name.to_string();
        let mut sim = Simulation::new(c.clone()).unwrap();
        let mut wl =
            DiurnalWorkload::new(c.workload.clone(), sim.ctx.topo.n, c.seed);
        let mut twin =
            DiurnalWorkload::new(c.workload.clone(), sim.ctx.topo.n, c.seed);
        let mut generated = 0u64;
        for slot in 0..c.slots {
            generated += twin.slot_tasks(slot, c.slot_secs).len() as u64;
        }
        let mut sched = torta::scheduler::build(sched_name, &sim.ctx, &c).unwrap();
        let m = sim.run(&mut wl, sched.as_mut());
        // served + dropped + still-buffered == generated
        assert_eq!(
            m.tasks_total + sim.backlog_len() as u64,
            generated,
            "{sched_name}: conservation violated"
        );
        // Bounds.
        if m.response.len() > 0 {
            assert!(m.mean_response() > 0.0);
            assert!(m.waiting.mean() >= 0.0);
        }
        assert!(m.mean_lb() > 0.0 && m.mean_lb() <= 1.0);
        assert!(m.power_cost_dollars >= 0.0);
        assert!(m.switching_cost_frob >= -1e-12);
    });
}

#[test]
fn milp_solutions_always_feasible_and_ordered() {
    prop::check(15, |rng, size| {
        let n = 2 + rng.below(size.min(10));
        let p = AssignmentProblem::generate(n, rng.next_u64());
        let exact = solve_bnb(&p, 5_000_000).expect("bnb");
        validate(&p, &exact).expect("bnb feasible");
        let greedy = solve_greedy(&p).expect("greedy");
        validate(&p, &greedy).expect("greedy feasible");
        if exact.optimal {
            assert!(
                exact.cost <= greedy.cost + 1e-9,
                "exact {} > greedy {}",
                exact.cost,
                greedy.cost
            );
        }
    });
}

#[test]
fn switching_cost_zero_for_constant_allocation() {
    // A scheduler that reports the same alloc every slot accrues zero
    // Frobenius switching cost regardless of workload randomness.
    prop::check(8, |rng, _| {
        let cfg = random_cfg(rng);
        let mut m = torta::metrics::RunMetrics::new("const", "x");
        let r = 4;
        let alloc = prop::simplex(rng, r * r);
        for _ in 0..cfg.slots {
            m.record_alloc(&alloc);
        }
        assert!(m.switching_cost_frob.abs() < 1e-12);
    });
}
