//! Regression suite for the coordinator hot-path overhaul (§Perf PR):
//!
//! * warm-started Sinkhorn must match cold-start transport cost on a
//!   drifting 20-slot marginal sequence (the temporal-coherence trick must
//!   not change the answer);
//! * early exit must never terminate above the configured tolerance;
//! * the lazy bound-heap micro matcher must reproduce the reference
//!   full-rescan matcher assignment-for-assignment;
//! * every scheduler must produce bit-identical `SlotPlan`s for a fixed
//!   seed (determinism preserved across the refactor).

use torta::cluster::Fleet;
use torta::config::{ExperimentConfig, WorkloadConfig};
use torta::ot::{self, SinkhornSolver};
use torta::power::PriceTable;
use torta::scheduler::torta::micro::MicroAllocator;
use torta::sim::{topo_salt, Simulation};
use torta::topology::Topology;
use torta::util::prop;
use torta::util::rng::Rng;
use torta::workload::{DiurnalWorkload, Task, WorkloadSource};

/// Deterministic drifting marginal: a base simplex nudged by a smooth
/// per-slot perturbation, renormalized.
fn drifted(base: &[f64], slot: usize, phase: f64) -> Vec<f64> {
    let raw: Vec<f64> = base
        .iter()
        .enumerate()
        .map(|(i, &m)| (m + 0.02 * (slot as f64 * 0.3 + i as f64 * phase).sin()).max(1e-4))
        .collect();
    let s: f64 = raw.iter().sum();
    raw.iter().map(|x| x / s).collect()
}

#[test]
fn warm_start_matches_cold_start_on_drifting_sequence() {
    let r = 12;
    let mut rng = Rng::seeded(21);
    let cost = prop::matrix(&mut rng, r, r, 0.0, 1.0);
    let base_mu = prop::simplex(&mut rng, r);
    let base_nu = prop::simplex(&mut rng, r);
    let max_iters = 100_000;
    let mut warm = SinkhornSolver::new(&cost, r, 0.05, 1e-7, max_iters);
    let mut warm_iters_total = 0usize;
    let mut cold_iters_total = 0usize;
    for slot in 0..20 {
        let mu = drifted(&base_mu, slot, 0.7);
        let nu = drifted(&base_nu, slot, 1.3);
        let plan_warm = warm.solve(&mu, &nu).to_vec();
        assert!(warm.last_iters < max_iters, "slot {slot}: warm solve did not converge");
        warm_iters_total += warm.last_iters;
        let mut cold = SinkhornSolver::new(&cost, r, 0.05, 1e-7, max_iters);
        let plan_cold = cold.solve(&mu, &nu).to_vec();
        cold_iters_total += cold.last_iters;
        let cw = ot::transport_cost(&cost, &plan_warm);
        let cc = ot::transport_cost(&cost, &plan_cold);
        assert!(
            (cw - cc).abs() < 1e-6,
            "slot {slot}: warm transport cost {cw} vs cold {cc}"
        );
    }
    // The whole point of warm starting: strictly fewer total iterations.
    assert!(
        warm_iters_total < cold_iters_total,
        "warm {warm_iters_total} !< cold {cold_iters_total}"
    );
}

#[test]
fn early_exit_never_terminates_above_tolerance() {
    let tol = 1e-6;
    let max_iters = 5000;
    let mut rng = Rng::seeded(33);
    let mut early_exits = 0;
    for case in 0..25 {
        let r = 2 + rng.below(20);
        let cost = prop::matrix(&mut rng, r, r, 0.0, 1.0);
        let mu = prop::simplex(&mut rng, r);
        let nu = prop::simplex(&mut rng, r);
        let mut solver = SinkhornSolver::new(&cost, r, 0.05, tol, max_iters);
        let plan = solver.solve(&mu, &nu).to_vec();
        if solver.last_iters < max_iters {
            early_exits += 1;
            assert!(
                solver.last_marginal_err <= tol,
                "case {case}: early exit at {} iters with err {}",
                solver.last_iters,
                solver.last_marginal_err
            );
            // And the reported error is the real row-marginal error of the
            // returned plan (small slack for summation-order rounding).
            let mut row_err = 0.0;
            for i in 0..r {
                let row: f64 = plan[i * r..(i + 1) * r].iter().sum();
                row_err += (row - mu[i]).abs();
            }
            assert!(
                row_err <= tol * 1.01 + 1e-12,
                "case {case}: plan row error {row_err} above tol {tol}"
            );
        }
    }
    assert!(early_exits > 0, "no case early-exited; tolerance test is vacuous");
}

#[test]
fn lazy_matcher_equals_scan_matcher_across_slots_and_load() {
    let topo = Topology::abilene();
    let prices = PriceTable::for_regions(topo.n, 9);
    let fleet = Fleet::build(&topo, &prices, 9);
    let micro = MicroAllocator::new(1.0, 0.25, 0.6, 0.15);
    // Default and high-rate (saturating → exercises the overflow path).
    for (wseed, wcfg) in [(5u64, WorkloadConfig::default()), (6, WorkloadConfig::high_rate())] {
        let mut wl = DiurnalWorkload::new(wcfg, topo.n, wseed);
        for slot in 0..4 {
            let now = slot as f64 * 45.0;
            let tasks = wl.slot_tasks(slot, 45.0);
            for region in 0..topo.n {
                let batch: Vec<Task> =
                    tasks.iter().filter(|t| t.origin == region).cloned().collect();
                if batch.is_empty() {
                    continue;
                }
                let (a_lazy, o_lazy) = micro.match_region(&fleet, region, batch.clone(), now);
                let (a_scan, o_scan) = micro.match_region_scan(&fleet, region, batch, now);
                assert_eq!(a_lazy.len(), a_scan.len(), "region {region} slot {slot}");
                for (k, ((tl, rl, sl), (ts, rs, ss))) in
                    a_lazy.iter().zip(a_scan.iter()).enumerate()
                {
                    assert_eq!(tl.id, ts.id, "assignment {k} region {region}");
                    assert_eq!(rl, rs);
                    assert_eq!(sl, ss, "task {} routed to different server", tl.id);
                }
                assert_eq!(o_lazy.len(), o_scan.len());
                for (x, y) in o_lazy.iter().zip(o_scan.iter()) {
                    assert_eq!(x.id, y.id);
                }
            }
        }
    }
}

/// Drive a scheduler slot-by-slot (mirroring the engine's tick/schedule/
/// execute loop) and collect a compact fingerprint of every `SlotPlan`.
fn run_plans(name: &str, slots: usize) -> Vec<(Vec<(u64, usize, usize)>, Vec<f64>)> {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = name.into();
    cfg.slots = slots;
    cfg.torta.use_pjrt = false;
    let mut sim = Simulation::new(cfg.clone()).unwrap();
    let mut wl = DiurnalWorkload::new(
        cfg.workload.clone(),
        sim.ctx.topo.n,
        cfg.seed ^ topo_salt(&cfg.topology),
    );
    let mut sched = torta::scheduler::build(name, &sim.ctx, &cfg).unwrap();
    let mut plans = Vec::with_capacity(slots);
    for slot in 0..slots {
        let now = slot as f64 * cfg.slot_secs;
        for region in &mut sim.fleet.regions {
            for s in &mut region.servers {
                s.tick_state(now);
            }
        }
        let tasks = wl.slot_tasks(slot, cfg.slot_secs);
        let plan = sched.schedule(&sim.ctx, &mut sim.fleet, tasks, slot, now);
        sim.fleet.invalidate_aggregates();
        for (task, region, si) in &plan.assignments {
            sim.fleet.regions[*region].servers[*si].assign(task, now);
        }
        let fp: Vec<(u64, usize, usize)> =
            plan.assignments.iter().map(|(t, r, s)| (t.id, *r, *s)).collect();
        plans.push((fp, plan.alloc));
    }
    plans
}

#[test]
fn tol_zero_macro_path_is_bit_identical_to_pre_refactor_solver() {
    // The pre-PR macro layer solved `ot::sinkhorn(cost, mu, nu, eps,
    // iters)` cold every slot. That free function is unchanged, so it is
    // the before-refactor oracle: with `sinkhorn_tol = 0` the new
    // warm-started solver path must reproduce it bit-for-bit across a
    // slot sequence (no early exit, cold start per slot).
    use torta::scheduler::torta::macro_alloc::MacroAllocator;
    let r = 12;
    let mut rng = Rng::seeded(77);
    let cost = prop::matrix(&mut rng, r, r, 0.0, 1.0);
    let base_mu = prop::simplex(&mut rng, r);
    let base_nu = prop::simplex(&mut rng, r);
    let mut m = MacroAllocator::new(r, 0.6, 0.5, 0.05, 50);
    m.sinkhorn_tol = 0.0;
    for slot in 0..10 {
        let mu = drifted(&base_mu, slot, 0.9);
        let nu = drifted(&base_nu, slot, 1.7);
        let got = m.ot_probabilities(&cost, &mu, &nu, None);
        let want = ot::row_normalize(&ot::sinkhorn(&cost, &mu, &nu, 0.05, 50), r);
        assert_eq!(got, want, "slot {slot}: tol=0 path diverged from pre-refactor solver");
    }
}

#[test]
fn all_schedulers_produce_bit_identical_slot_plans() {
    for name in ["torta-native", "reactive", "skylb", "sdib", "rr"] {
        let a = run_plans(name, 8);
        let b = run_plans(name, 8);
        assert_eq!(a.len(), b.len());
        for (slot, (pa, pb)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(pa.0, pb.0, "{name}: assignments differ at slot {slot}");
            // Bitwise allocation-matrix equality, not approximate.
            assert_eq!(pa.1, pb.1, "{name}: alloc matrix differs at slot {slot}");
        }
    }
}
