//! Persistent worker-pool contract tests (docs/PERF.md, "Shard
//! pipeline"): index-ordered fan-in under adversarial per-item delays,
//! panic propagation with pool survival, batch reuse without thread
//! growth (via the `spawned_workers` hook), equivalence against the
//! retained `scoped_map` reference, and nested-batch deadlock freedom.
//!
//! The spawn counter is process-global and monotone, so every test in
//! this binary keeps its width within `MAX_WIDTH` and the growth test
//! pre-warms to that width before snapshotting — concurrent test
//! threads then cannot trigger additional spawns.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use torta::util::pool::{parallel_map, scoped_map, spawned_workers, WorkerPool};

/// Widest pool any test in this binary engages. The growth test warms to
/// this width first, so no other test can spawn past its snapshot.
const MAX_WIDTH: usize = 8;

#[test]
fn ordered_fanin_under_adversarial_delays() {
    // Later items finish FIRST (reverse-proportional sleeps), so any
    // completion-order fan-in would return them scrambled; the pool must
    // still return input order.
    let n = 24usize;
    let out = parallel_map((0..n).collect::<Vec<_>>(), 4, |i| {
        std::thread::sleep(Duration::from_millis(2 * (n - i) as u64));
        i * 10
    });
    assert_eq!(out, (0..n).map(|i| i * 10).collect::<Vec<_>>());
}

#[test]
fn panic_propagates_and_pool_survives() {
    let result = catch_unwind(AssertUnwindSafe(|| {
        parallel_map(vec![0usize, 1, 2, 3, 4, 5], 4, |i| {
            if i == 3 {
                panic!("boom from item {i}");
            }
            i
        })
    }));
    let payload = result.expect_err("worker panic must reach the caller");
    let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
    assert!(msg.contains("boom from item 3"), "unexpected payload: {msg:?}");
    // The panic was caught per-item, so no pool worker died: the very
    // next batch completes normally on the same workers.
    let out = parallel_map(vec![1, 2, 3], 4, |x| x * 2);
    assert_eq!(out, vec![2, 4, 6]);
}

#[test]
fn sequential_batches_reuse_workers_without_thread_growth() {
    // Warm the pool to the widest width this binary ever uses, then
    // snapshot the monotone spawn counter: three more batches (plus a
    // handle re-creation) must not spawn a single extra thread.
    let pool = WorkerPool::new(MAX_WIDTH);
    pool.map((0..32usize).collect::<Vec<_>>(), |i| i + 1);
    let spawned_before = spawned_workers();
    assert!(spawned_before >= MAX_WIDTH - 1, "warm-up must have spawned helpers");
    for batch in 0..3usize {
        let out = pool.map((0..64usize).collect::<Vec<_>>(), move |i| i * (batch + 1));
        assert_eq!(out, (0..64).map(|i| i * (batch + 1)).collect::<Vec<_>>());
    }
    let again = WorkerPool::new(MAX_WIDTH);
    again.map(vec![1usize, 2, 3], |x| x);
    assert_eq!(
        spawned_workers(),
        spawned_before,
        "batches on a warm pool must reuse workers, not spawn new ones"
    );
}

#[test]
fn pool_matches_scoped_reference_and_sequential() {
    let xs: Vec<i64> = (0..513).collect();
    let f = |x: i64| x.wrapping_mul(x) - 7 * x + 1;
    let pool_out = parallel_map(xs.clone(), 4, f);
    let scoped_out = scoped_map(xs.clone(), 4, f);
    let seq_out: Vec<i64> = xs.into_iter().map(f).collect();
    assert_eq!(pool_out, scoped_out);
    assert_eq!(pool_out, seq_out);
}

#[test]
fn zero_workers_resolves_and_overwide_requests_clamp() {
    // workers == 0 resolves through the resolve_threads chain (one
    // place), and a width far beyond the item count must still return
    // every item exactly once in order.
    let out = parallel_map(vec![10, 20, 30], 0, |x| x + 1);
    assert_eq!(out, vec![11, 21, 31]);
    let out = parallel_map(vec![1, 2], MAX_WIDTH, |x| x * 5);
    assert_eq!(out, vec![5, 10]);
}

#[test]
fn nested_batches_progress_when_all_workers_busy() {
    // Caller-helps-drain: even with the outer batch occupying the pool,
    // each inner batch completes (its submitter drains it alone if need
    // be). A missed wake-up or submit-and-wait design would deadlock
    // here; bound the whole thing with a wall-clock assert.
    let t0 = Instant::now();
    let hits = AtomicUsize::new(0);
    let outer = parallel_map(vec![0usize, 1, 2, 3, 4, 5], MAX_WIDTH, |base| {
        let inner = parallel_map((0..8usize).collect::<Vec<_>>(), 4, |k| {
            hits.fetch_add(1, Ordering::Relaxed);
            base * 100 + k
        });
        inner.iter().sum::<usize>()
    });
    assert_eq!(outer.len(), 6);
    assert_eq!(hits.load(Ordering::Relaxed), 48);
    for (base, total) in outer.into_iter().enumerate() {
        assert_eq!(total, (0..8).map(|k| base * 100 + k).sum::<usize>());
    }
    assert!(t0.elapsed() < Duration::from_secs(30), "nested batches stalled");
}
