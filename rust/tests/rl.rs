//! Integration tests for the native RL training subsystem (`rl/`):
//! seed-determinism of training, artifact round-trips, the trained-policy
//! eval path through `PolicyProvider`, the no-artifact fallback identity,
//! and (ignored by default, run in the full-suite CI job) the
//! learning-curve improvement on the surge scenario.

use std::path::PathBuf;

use torta::config::ExperimentConfig;
use torta::rl::{
    self, Algo, AllocQuery, NativePolicy, PolicyProvider, PpoConfig, RewardWeights, TrainConfig,
};
use torta::scheduler::torta::{TortaMode, TortaScheduler};
use torta::scheduler::Scheduler;
use torta::sim::run_experiment;
use torta::workload::WorkloadSource;

fn tiny_cfg(topology: &str, scenario: &str, slots: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.topology = topology.into();
    cfg.slots = slots;
    cfg.workload.base_rate = 10.0;
    cfg.torta.use_pjrt = false;
    cfg.scenario = torta::scenario::Scenario::by_name(scenario).unwrap();
    cfg
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("torta_rl_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn training_is_seed_deterministic() {
    let cfg = tiny_cfg("synthetic-4", "diurnal", 6);
    let tc = TrainConfig { episodes: 3, seed: 11, ..Default::default() };
    let (pa, ra) = rl::train(&cfg, &tc).unwrap();
    let (pb, rb) = rl::train(&cfg, &tc).unwrap();
    // Same seed: bit-identical weights and learning curves.
    assert_eq!(pa.w.len(), pb.w.len());
    for (x, y) in pa.w.iter().zip(&pb.w) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in pa.b.iter().zip(&pb.b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in ra.episode_returns.iter().zip(&rb.episode_returns) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    // A different seed diverges (init, exploration and fleet all shift).
    let tc2 = TrainConfig { episodes: 3, seed: 12, ..Default::default() };
    let (pc, _) = rl::train(&cfg, &tc2).unwrap();
    assert!(pa.w.iter().zip(&pc.w).any(|(x, y)| x != y));
}

#[test]
fn trained_policy_save_load_alloc_roundtrips_bitwise() {
    // Train a couple of episodes so the weights are off-init, then prove
    // save -> load -> alloc is bit-identical.
    let cfg = tiny_cfg("synthetic-4", "diurnal", 5);
    let tc = TrainConfig { episodes: 2, seed: 5, ..Default::default() };
    let (policy, _) = rl::train(&cfg, &tc).unwrap();
    let path = tmp_dir("roundtrip").join("policy.json");
    policy.save(&path).unwrap();
    let back = NativePolicy::load(&path).unwrap();
    assert_eq!(back.r, policy.r);
    assert_eq!(back.episodes, 2);
    assert_eq!(back.scenario, "diurnal");
    for (x, y) in policy.w.iter().zip(&back.w) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in policy.b.iter().zip(&back.b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    // Alloc outputs agree bitwise on arbitrary states.
    let mut state = vec![0.0f32; policy.d];
    for (i, x) in state.iter_mut().enumerate() {
        *x = ((i * 37 + 11) % 97) as f32 / 97.0;
    }
    let q = AllocQuery { slot: 0, ot: &[] };
    let a = policy.alloc(&state, &q).unwrap();
    let b = back.alloc(&state, &q).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn train_cli_artifact_loads_into_simulate_via_policy_provider() {
    // The acceptance loop, in-process: train -> save artifact -> a config
    // pointing `torta.policy_path` at it -> `simulate --scheduler torta`
    // runs with the trained policy through the PolicyProvider seam.
    let cfg = tiny_cfg("synthetic-5", "surge", 8);
    let tc = TrainConfig { episodes: 2, seed: 7, ..Default::default() };
    let (policy, _) = rl::train(&cfg, &tc).unwrap();
    let dir = tmp_dir("eval");
    let path = NativePolicy::default_path(&dir, policy.r);
    policy.save(&path).unwrap();

    let mut eval_cfg = cfg.clone();
    eval_cfg.scheduler = "torta".into();
    eval_cfg.torta.policy_path = path.to_string_lossy().into_owned();
    let ctx = rl::scheduler_ctx(&eval_cfg).unwrap();
    let sched = torta::scheduler::build("torta", &ctx, &eval_cfg).unwrap();
    assert_eq!(sched.name(), "torta");
    let m = run_experiment(&eval_cfg).unwrap();
    assert!(m.tasks_total > 0);
    assert!(m.completion_rate() > 0.3);
    std::fs::remove_file(&path).ok();
}

#[test]
fn trained_policy_decisions_stay_valid_and_trust_region_bounded() {
    // Valid SlotDecisions: every offered task is assigned or buffered and
    // the executed alloc stays row-stochastic; and on the first slot
    // (identical fleet state, hence identical OT anchor) the policy-driven
    // alloc sits within 2 * eps_max of the fallback's, as the shared
    // trust region requires.
    let mut cfg = tiny_cfg("synthetic-5", "diurnal", 6);
    cfg.torta.eps_max = 0.2;
    let tc = TrainConfig { episodes: 2, seed: 3, ..Default::default() };
    let (policy, _) = rl::train(&cfg, &tc).unwrap();
    let r = policy.r;

    let ctx = rl::scheduler_ctx(&cfg).unwrap();
    let mut with_policy = TortaScheduler::new(&ctx, &cfg.torta, TortaMode::Native, cfg.seed)
        .with_policy(Box::new(policy));
    let mut fallback = TortaScheduler::new(&ctx, &cfg.torta, TortaMode::Native, cfg.seed);

    let seed = cfg.seed ^ torta::sim::topo_salt(&ctx.topo.name);
    let mut wl = cfg.scenario.build_workload(&cfg.workload, r, seed, cfg.slot_secs).unwrap();
    let mut wl_twin = cfg.scenario.build_workload(&cfg.workload, r, seed, cfg.slot_secs).unwrap();
    let mut fleet_a = torta::cluster::Fleet::build(&ctx.topo, &ctx.prices, seed);
    let mut fleet_b = fleet_a.clone();

    for slot in 0..cfg.slots {
        let now = slot as f64 * cfg.slot_secs;
        let tasks = wl.slot_tasks(slot, cfg.slot_secs);
        let twin_tasks = wl_twin.slot_tasks(slot, cfg.slot_secs);
        let n = tasks.len();
        let plan = with_policy.schedule(&ctx, &mut fleet_a, tasks, slot, now);
        let plan_fb = fallback.schedule(&ctx, &mut fleet_b, twin_tasks, slot, now);
        assert_eq!(plan.assignments.len() + plan.buffered.len(), n, "slot {slot}");
        for i in 0..r {
            let s: f64 = plan.alloc[i * r..(i + 1) * r].iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "slot {slot} row {i} sums {s}");
            assert!(plan.alloc[i * r..(i + 1) * r].iter().all(|&x| x >= 0.0));
        }
        if slot == 0 {
            // Both allocs are within eps_max of the same OT anchor.
            let dist = plan
                .alloc
                .iter()
                .zip(&plan_fb.alloc)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f64>()
                .sqrt();
            assert!(
                dist <= 2.0 * cfg.torta.eps_max + 0.1,
                "slot-0 allocs {dist} apart despite shared trust region"
            );
        }
    }
}

#[test]
fn no_artifact_torta_is_bit_identical_to_native_fallback() {
    // With no PJRT artifacts and no native policy, the Full-mode "torta"
    // scheduler must take exactly the native fallback path: identical
    // dynamics to "torta-native", bit for bit.
    let mut cfg = tiny_cfg("abilene", "diurnal", 10);
    cfg.torta.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.scheduler = "torta".into();
    let full = run_experiment(&cfg).unwrap();
    cfg.scheduler = "torta-native".into();
    let native = run_experiment(&cfg).unwrap();
    assert_eq!(full.tasks_total, native.tasks_total);
    assert_eq!(full.tasks_dropped, native.tasks_dropped);
    assert_eq!(full.migrations, native.migrations);
    assert_eq!(full.mean_response().to_bits(), native.mean_response().to_bits());
    assert_eq!(full.switching_cost_frob.to_bits(), native.switching_cost_frob.to_bits());
    assert_eq!(full.power_cost_dollars.to_bits(), native.power_cost_dollars.to_bits());
}

#[test]
fn policy_dimension_mismatch_falls_back_gracefully() {
    // An R=4 policy pointed at an R=12 topology must not panic or skew
    // the run: the scheduler warns and takes the native fallback.
    let policy = NativePolicy::init(4, 1);
    let dir = tmp_dir("mismatch");
    let path = NativePolicy::default_path(&dir, 4);
    policy.save(&path).unwrap();
    let mut cfg = tiny_cfg("abilene", "diurnal", 6);
    cfg.scheduler = "torta".into();
    cfg.torta.policy_path = path.to_string_lossy().into_owned();
    let with_bad_policy = run_experiment(&cfg).unwrap();
    cfg.torta.policy_path = String::new();
    let clean = run_experiment(&cfg).unwrap();
    assert_eq!(with_bad_policy.mean_response().to_bits(), clean.mean_response().to_bits());
    std::fs::remove_file(&path).ok();
}

/// The slot-alignment contract the trainer's credit assignment rests on:
/// the scheduler consults the provider at most once per engine slot, in
/// strictly increasing slot order, with the slot's OT anchor attached —
/// even when the provider declines some slots (which must only route
/// those slots to the fallback, not shift later calls). This is the
/// regression test for the historical bug where declined slots silently
/// shifted reward credit onto the wrong steps.
#[test]
fn declining_provider_calls_stay_slot_aligned() {
    use std::cell::RefCell;
    use std::rc::Rc;

    struct Declining {
        inner: NativePolicy,
        decline: Vec<usize>,
        seen: Rc<RefCell<Vec<(usize, usize)>>>,
    }
    impl PolicyProvider for Declining {
        fn name(&self) -> &'static str {
            "declining"
        }
        fn alloc(&self, state: &[f32], q: &AllocQuery) -> Option<Vec<f64>> {
            self.seen.borrow_mut().push((q.slot, q.ot.len()));
            if self.decline.contains(&q.slot) {
                return None;
            }
            self.inner.alloc(state, q)
        }
    }

    let cfg = tiny_cfg("synthetic-5", "diurnal", 8);
    let seen = Rc::new(RefCell::new(Vec::new()));
    let provider = Declining {
        inner: NativePolicy::init(5, 3),
        decline: vec![1, 4, 5],
        seen: seen.clone(),
    };
    let ctx = rl::scheduler_ctx(&cfg).unwrap();
    let mut sched = TortaScheduler::new(&ctx, &cfg.torta, TortaMode::Native, cfg.seed)
        .with_policy(Box::new(provider));
    let trace = rl::run_episode(&cfg, &mut sched, &RewardWeights::default()).unwrap();
    assert_eq!(trace.rewards.len(), cfg.slots);

    let seen = seen.borrow();
    assert!(!seen.is_empty());
    let mut prev: Option<usize> = None;
    for &(slot, ot_len) in seen.iter() {
        assert!(slot < cfg.slots, "slot {slot} outside horizon");
        assert_eq!(ot_len, 25, "OT anchor must be the full R x R plan");
        if let Some(p) = prev {
            assert!(slot > p, "provider called out of order: {slot} after {p}");
        }
        prev = Some(slot);
    }
}

fn small_ppo(episodes: usize, threads: usize) -> TrainConfig {
    TrainConfig {
        algo: Algo::Ppo,
        episodes,
        seed: 11,
        threads,
        ppo: PpoConfig { rollouts_per_update: 4, minibatch: 16, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn ppo_training_is_seed_deterministic() {
    let cfg = tiny_cfg("synthetic-4", "diurnal", 6);
    let tc = small_ppo(4, 1);
    let (pa, ra) = rl::train(&cfg, &tc).unwrap();
    let (pb, rb) = rl::train(&cfg, &tc).unwrap();
    for (x, y) in pa.w.iter().zip(&pb.w) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in pa.b.iter().zip(&pb.b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in ra.episode_returns.iter().zip(&rb.episode_returns) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert_eq!(pa.algo, "ppo");
    let mut tc2 = tc.clone();
    tc2.seed = 12;
    let (pc, _) = rl::train(&cfg, &tc2).unwrap();
    assert!(pa.w.iter().zip(&pc.w).any(|(x, y)| x != y));
}

/// The parallel-rollout determinism contract (docs/RL.md): PPO training
/// is bit-identical at every worker count, because exploration streams
/// derive from the global episode index and the fan-in preserves episode
/// order. Style of `shard_equivalence.rs`: sequential run as the oracle.
#[test]
fn ppo_rollouts_are_bitwise_equivalent_across_thread_counts() {
    let cfg = tiny_cfg("synthetic-4", "diurnal", 6);
    let (oracle_p, oracle_r) = rl::train(&cfg, &small_ppo(8, 1)).unwrap();
    // Non-vacuous: the oracle actually learned something off-init.
    let init = NativePolicy::init(4, 11);
    assert!(oracle_p.w.iter().zip(&init.w).any(|(a, b)| a != b));
    assert_eq!(oracle_r.episode_returns.len(), 8);
    for threads in [2, 4] {
        let (p, r) = rl::train(&cfg, &small_ppo(8, threads)).unwrap();
        for (x, y) in p.w.iter().zip(&oracle_p.w) {
            assert_eq!(x.to_bits(), y.to_bits(), "weights diverged at {threads} threads");
        }
        for (x, y) in p.b.iter().zip(&oracle_p.b) {
            assert_eq!(x.to_bits(), y.to_bits(), "bias diverged at {threads} threads");
        }
        for (x, y) in r.episode_returns.iter().zip(&oracle_r.episode_returns) {
            assert_eq!(x.to_bits(), y.to_bits(), "returns diverged at {threads} threads");
        }
    }
}

/// Clipped-update invariants on the per-update diagnostics: the clip
/// fraction is a fraction, the deviation metric is non-negative, the
/// constraint weights only escalate (multiplicatively, from 1), and a
/// truncated final batch still accounts for every episode.
#[test]
fn ppo_report_satisfies_clipped_update_invariants() {
    let cfg = tiny_cfg("synthetic-4", "diurnal", 6);
    let mut tc = small_ppo(6, 2);
    tc.ppo.rollouts_per_update = 4; // batches of 4 + 2
    let (policy, report) = rl::train(&cfg, &tc).unwrap();
    assert_eq!(report.episode_returns.len(), 6);
    assert_eq!(report.ppo_updates.len(), 2);
    let (mut gamma_prev, mut delta_prev) = (1.0, 1.0);
    for u in &report.ppo_updates {
        assert!((0.0..=1.0).contains(&u.clip_frac), "clip_frac {}", u.clip_frac);
        assert!(u.dev >= 0.0);
        assert!(u.s_current >= 0.0);
        assert!(u.eval_return.is_finite());
        assert!(u.mean_return.is_finite());
        assert!(u.gamma_c >= gamma_prev, "gamma_c shrank: {}", u.gamma_c);
        assert!(u.delta_c >= delta_prev, "delta_c shrank: {}", u.delta_c);
        gamma_prev = u.gamma_c;
        delta_prev = u.delta_c;
    }
    // Provenance is stamped for the artifact round trip.
    assert_eq!(policy.algo, "ppo");
    assert_eq!(policy.gamma.to_bits(), tc.gamma.to_bits());
    let path = tmp_dir("ppo_provenance").join("policy.json");
    policy.save(&path).unwrap();
    let back = NativePolicy::load(&path).unwrap();
    assert_eq!(back.algo, "ppo");
    assert_eq!(back.weights, policy.weights);
    std::fs::remove_file(&path).ok();
}

/// Learning-curve test (slow, statistical): REINFORCE on the surge
/// scenario against a fixed (deterministic) environment must improve both
/// the greedy policy and the smoothed sampled returns. Excluded from
/// tier-1 `cargo test -q`; the full-suite CI job runs it with
/// `--include-ignored`.
#[test]
#[ignore = "slow statistical training run; covered by the full-suite CI job"]
fn reward_improves_over_episodes_on_surge() {
    let mut cfg = tiny_cfg("synthetic-6", "surge", 40);
    cfg.workload.base_rate = 30.0;
    let tc = TrainConfig { episodes: 36, lr: 0.1, seed: 42, ..Default::default() };
    let weights = RewardWeights::default();
    let init = NativePolicy::init(6, tc.seed);
    let before = rl::eval(&cfg, &init, &weights).unwrap();
    let (trained, report) = rl::train(&cfg, &tc).unwrap();
    let after = rl::eval(&cfg, &trained, &weights).unwrap();
    // (a) Greedy policy improves over its init on the deterministic env.
    assert!(
        after.total_reward > before.total_reward,
        "greedy eval did not improve: {} -> {}",
        before.total_reward,
        after.total_reward
    );
    // (b) Smoothed sampled returns trend upward (windowed, not strict).
    let smoothed = report.smoothed();
    let w = 6;
    let early: f64 = report.episode_returns[..w].iter().sum::<f64>() / w as f64;
    let late: f64 = report.episode_returns[tc.episodes - w..].iter().sum::<f64>() / w as f64;
    assert!(
        late > early,
        "smoothed returns did not trend up: early {early:.2} late {late:.2} (curve {smoothed:?})"
    );
}
