//! Integration over the PJRT runtime: the AOT artifacts produced by
//! `make artifacts` must load, execute, and agree with the native
//! implementations (L1 kernel <-> L3 solver equivalence).
//!
//! Skipped (with a notice) when artifacts are absent so `cargo test` works
//! on a fresh checkout; CI runs `make artifacts` first.

use std::path::PathBuf;

use torta::ot;
use torta::runtime::TortaArtifacts;
use torta::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    // Tests run from the crate root.
    torta::runtime::default_artifacts_dir()
}

fn load(r: usize) -> Option<TortaArtifacts> {
    let dir = artifacts_dir();
    if !TortaArtifacts::available(&dir, r) {
        eprintln!("SKIP: artifacts for R={r} missing in {dir:?}; run `make artifacts`");
        return None;
    }
    Some(TortaArtifacts::load(&dir, r).expect("artifact load"))
}

fn simplex32(rng: &mut Rng, n: usize) -> Vec<f32> {
    let v = torta::util::prop::simplex(rng, n);
    v.iter().map(|&x| x as f32).collect()
}

#[test]
fn sinkhorn_artifact_matches_native_solver() {
    for r in [12, 25, 32] {
        let Some(art) = load(r) else { return };
        let mut rng = Rng::seeded(7 + r as u64);
        for case in 0..5 {
            let mu = simplex32(&mut rng, r);
            let nu = simplex32(&mut rng, r);
            let c: Vec<f32> = (0..r * r).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
            let got = art.sinkhorn_plan(&c, &mu, &nu).expect("pjrt sinkhorn");
            let want = ot::sinkhorn(
                &c.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                &mu.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                &nu.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                0.05,
                50,
            );
            for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (g as f64 - w).abs() < 1e-4,
                    "R={r} case={case} idx={i}: pjrt {g} vs native {w}"
                );
            }
        }
    }
}

#[test]
fn policy_artifact_outputs_row_stochastic_alloc() {
    for r in [12, 25, 32] {
        let Some(art) = load(r) else { return };
        let d = 4 * r + r * r;
        let mut rng = Rng::seeded(3);
        let state: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        let alloc = art.policy_alloc(&state).expect("policy run");
        assert_eq!(alloc.len(), r * r);
        for i in 0..r {
            let row: f32 = alloc[i * r..(i + 1) * r].iter().sum();
            assert!((row - 1.0).abs() < 1e-4, "R={r} row {i} sums {row}");
            assert!(alloc[i * r..(i + 1) * r].iter().all(|&x| x >= 0.0));
        }
    }
}

#[test]
fn policy_artifact_is_deterministic() {
    let Some(art) = load(12) else { return };
    let d = 4 * 12 + 144;
    let state = vec![0.25f32; d];
    let a = art.policy_alloc(&state).unwrap();
    let b = art.policy_alloc(&state).unwrap();
    assert_eq!(a, b);
}

#[test]
fn predictor_artifact_outputs_distribution() {
    for r in [12, 25, 32] {
        let Some(art) = load(r) else { return };
        let d = 15 * r;
        let mut rng = Rng::seeded(9);
        let hist: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        let pred = art.predict(&hist).expect("predictor run");
        assert_eq!(pred.len(), r);
        let sum: f32 = pred.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "R={r} predictor sums {sum}");
        assert!(pred.iter().all(|&x| x >= 0.0));
    }
}

#[test]
fn wrong_input_dims_rejected() {
    let Some(art) = load(12) else { return };
    assert!(art.policy_alloc(&[0.0; 7]).is_err());
    assert!(art.predict(&[0.0; 7]).is_err());
    assert!(art.sinkhorn_plan(&[0.0; 4], &[0.0; 2], &[0.0; 2]).is_err());
}

#[test]
fn full_torta_uses_artifacts_end_to_end() {
    let Some(_) = load(12) else { return };
    let mut cfg = torta::config::ExperimentConfig::default();
    cfg.slots = 16;
    cfg.scheduler = "torta".into();
    let m = torta::sim::run_experiment(&cfg).expect("full torta run");
    assert!(m.tasks_total > 0);
    assert!(m.completion_rate() > 0.9);
}
