//! Scenario-API oracle tests.
//!
//! The scenario redesign must not move a single bit of the legacy
//! behavior it replaces:
//!
//! * composed `WorkloadSource` stacks reproduce the legacy hard-coded
//!   generators' task streams bit-for-bit (`Surge::wrap(Diurnal)` vs the
//!   retained `SurgeWorkload` reference, scenario-built diurnal vs a
//!   directly constructed `Diurnal`);
//! * `run_experiment` through the default scenario yields `RunMetrics`
//!   bit-identical to the pre-refactor explicit-workload path for every
//!   scheduler;
//! * every registry scenario yields deterministic, arrival-sorted,
//!   unique-id streams and runs all four schedulers end-to-end;
//! * trace record -> replay round-trips bit-identically and drives a
//!   full run via the `trace:<path>` scenario.

use torta::config::{ExperimentConfig, WorkloadConfig};
use torta::scenario::{Scenario, REGISTRY};
use torta::sim::{run_experiment, topo_salt, Simulation};
use torta::workload::combinators::Surge;
use torta::workload::{DemandForecast, Diurnal, SurgeWindow, WorkloadSource};

fn small_cfg(scheduler: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.slots = 10;
    cfg.scheduler = scheduler.into();
    cfg.torta.use_pjrt = false;
    cfg
}

const SCHEDULERS: [&str; 4] = ["torta", "skylb", "sdib", "rr"];

#[test]
#[allow(deprecated)]
fn surge_wrap_reproduces_legacy_surge_bitwise() {
    use torta::workload::SurgeWorkload;
    let windows = [(5usize, 12usize, 2.5f64, None), (8, 20, 1.5, Some(3))];
    let mk = || Diurnal::new(WorkloadConfig::default(), 6, 11);
    let mut legacy = SurgeWorkload::new(mk(), windows.to_vec());
    let mut composed = Surge::wrap(
        mk(),
        windows
            .iter()
            .map(|&(s, e, f, r)| SurgeWindow { start_slot: s, end_slot: e, factor: f, region: r })
            .collect(),
    );
    for slot in 0..24 {
        let ra = legacy.rate_at(slot);
        let rb = composed.rate_at(slot);
        for (a, b) in ra.iter().zip(rb.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "rate bits differ at slot {slot}");
        }
        let ta = legacy.slot_tasks(slot, 45.0);
        let tb = composed.slot_tasks(slot, 45.0);
        assert_eq!(ta.len(), tb.len(), "stream length differs at slot {slot}");
        for (a, b) in ta.iter().zip(tb.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.origin, b.origin);
            assert_eq!(a.class, b.class);
            assert_eq!(a.model, b.model);
            assert_eq!(a.user, b.user);
            assert_eq!(a.service_secs.to_bits(), b.service_secs.to_bits());
            assert_eq!(a.arrival_secs.to_bits(), b.arrival_secs.to_bits());
            assert_eq!(a.deadline_secs.to_bits(), b.deadline_secs.to_bits());
            for (x, y) in a.embed.iter().zip(b.embed.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}

#[test]
fn scenario_diurnal_reproduces_direct_diurnal_bitwise() {
    let wl_cfg = WorkloadConfig::default();
    let mut direct = Diurnal::new(wl_cfg.clone(), 12, 99);
    let mut built = Scenario::diurnal().build_workload(&wl_cfg, 12, 99, 45.0).unwrap();
    for slot in 0..8 {
        assert_eq!(direct.rate_at(slot), built.rate_at(slot));
        let a = direct.slot_tasks(slot, 45.0);
        let b = built.slot_tasks(slot, 45.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_secs.to_bits(), y.arrival_secs.to_bits());
            assert_eq!(x.service_secs.to_bits(), y.service_secs.to_bits());
        }
    }
}

#[test]
fn default_scenario_metrics_match_prerefactor_path() {
    // The pre-refactor run_experiment built the diurnal workload
    // explicitly and never applied failures; the scenario path must be
    // bit-identical for every scheduler.
    for sched in SCHEDULERS {
        let cfg = small_cfg(sched);
        let a = run_experiment(&cfg).unwrap();

        let mut sim = Simulation::new(cfg.clone()).unwrap();
        assert!(sim.failures.is_empty(), "{sched}: default scenario added failures");
        let mut wl = Diurnal::new(
            cfg.workload.clone(),
            sim.ctx.topo.n,
            cfg.seed ^ topo_salt(&cfg.topology),
        );
        let mut s = torta::scheduler::build(sched, &sim.ctx, &cfg).unwrap();
        let b = sim.run(&mut wl, s.as_mut());

        assert_eq!(a.tasks_total, b.tasks_total, "{sched}");
        assert_eq!(a.tasks_dropped, b.tasks_dropped, "{sched}");
        assert_eq!(a.deadline_misses, b.deadline_misses, "{sched}");
        assert_eq!(a.model_switches, b.model_switches, "{sched}");
        assert_eq!(a.server_activations, b.server_activations, "{sched}");
        assert_eq!(a.mean_response().to_bits(), b.mean_response().to_bits(), "{sched}");
        assert_eq!(a.waiting.mean().to_bits(), b.waiting.mean().to_bits(), "{sched}");
        assert_eq!(a.power_cost_dollars.to_bits(), b.power_cost_dollars.to_bits(), "{sched}");
        assert_eq!(a.switching_cost_frob.to_bits(), b.switching_cost_frob.to_bits(), "{sched}");
        assert_eq!(a.mean_lb().to_bits(), b.mean_lb().to_bits(), "{sched}");
    }
}

#[test]
fn registry_event_windows_reshape_rates() {
    // The surge windows (slots 30-50) and the flash crowd (at slot 24)
    // must actually move the expected-rate curve relative to the diurnal
    // baseline inside their windows — and leave it untouched outside.
    let wl_cfg = WorkloadConfig::default();
    let base = Diurnal::new(wl_cfg.clone(), 12, 7);
    let surge = Scenario::by_name("surge")
        .unwrap()
        .build_workload(&wl_cfg, 12, 7, 45.0)
        .unwrap();
    assert_eq!(surge.rate_at(10), base.rate_at(10), "outside surge window");
    for (s, b) in surge.rate_at(40).iter().zip(base.rate_at(40).iter()) {
        assert!((s / b - 2.5).abs() < 1e-9, "inside surge window: {s} vs {b}");
    }
    let flash = Scenario::by_name("flash-crowd")
        .unwrap()
        .build_workload(&wl_cfg, 12, 7, 45.0)
        .unwrap();
    assert_eq!(flash.rate_at(10), base.rate_at(10), "before flash crowd");
    let peak = flash.rate_at(30);
    let calm = base.rate_at(30);
    assert!((peak[0] / calm[0] - 4.0).abs() < 1e-9, "flash-crowd peak in region 0");
    assert_eq!(peak[1..], calm[1..], "flash crowd is region-local");
}

#[test]
fn registry_streams_deterministic_sorted_unique() {
    // Slots 0..6 cover the calm baseline; 28..36 sit inside the surge /
    // flash-crowd event windows so the modulated generation path is
    // exercised, not just the identity path.
    let slots: Vec<usize> = (0..6).chain(28..36).collect();
    for name in REGISTRY {
        let sc = Scenario::by_name(name).unwrap();
        let wl_cfg = WorkloadConfig::default();
        let mut a = sc.build_workload(&wl_cfg, 12, 7, 45.0).unwrap();
        let mut b = sc.build_workload(&wl_cfg, 12, 7, 45.0).unwrap();
        let mut seen = std::collections::HashSet::new();
        for &slot in &slots {
            let ta = a.slot_tasks(slot, 45.0);
            let tb = b.slot_tasks(slot, 45.0);
            assert_eq!(ta.len(), tb.len(), "{name}: nondeterministic length, slot {slot}");
            for (x, y) in ta.iter().zip(tb.iter()) {
                assert_eq!(x.id, y.id, "{name}");
                assert_eq!(x.arrival_secs.to_bits(), y.arrival_secs.to_bits(), "{name}");
            }
            for pair in ta.windows(2) {
                assert!(pair[0].arrival_secs <= pair[1].arrival_secs, "{name}: unsorted");
            }
            for t in &ta {
                assert!(t.origin < 12, "{name}: origin out of range");
                assert!(seen.insert(t.id), "{name}: duplicate id {}", t.id);
            }
        }
    }
}

#[test]
fn registry_scenarios_run_all_schedulers_end_to_end() {
    for name in REGISTRY {
        for sched in SCHEDULERS {
            let mut cfg = small_cfg(sched);
            // 40 slots cover the surge window (30-50) and the full
            // flash-crowd ramp/hold/decay (24..39), so every scheduler
            // runs through the active event windows, not just calm slots.
            cfg.slots = 40;
            cfg.workload.base_rate = 20.0; // keep the 20-run matrix quick
            cfg.scenario = Scenario::by_name(name).unwrap();
            let a = run_experiment(&cfg)
                .unwrap_or_else(|e| panic!("{sched} on {name} failed: {e}"));
            assert!(a.tasks_total > 0, "{sched} on {name}: no tasks");
            assert_eq!(a.scenario, name, "{sched}: scenario tag missing");
            // Deterministic across runs.
            let b = run_experiment(&cfg).unwrap();
            assert_eq!(a.tasks_total, b.tasks_total, "{sched} on {name}");
            assert_eq!(
                a.mean_response().to_bits(),
                b.mean_response().to_bits(),
                "{sched} on {name}"
            );
        }
    }
}

#[test]
fn tenant_mix_annotates_without_moving_the_arrival_stream() {
    // Token-mode oracle half 1: the tenant-mix stack's base stream (ids,
    // arrivals, service times) is bit-equal to plain diurnal — token
    // sampling lives on its own RNG stream (docs/SERVING.md).
    let wl_cfg = WorkloadConfig::default();
    let mut plain = Scenario::diurnal().build_workload(&wl_cfg, 12, 99, 45.0).unwrap();
    let mut token = Scenario::by_name("tenant-mix")
        .unwrap()
        .build_workload(&wl_cfg, 12, 99, 45.0)
        .unwrap();
    for slot in 0..8 {
        let a = plain.slot_tasks(slot, 45.0);
        let b = token.slot_tasks(slot, 45.0);
        assert_eq!(a.len(), b.len(), "slot {slot}");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_secs.to_bits(), y.arrival_secs.to_bits());
            assert_eq!(x.service_secs.to_bits(), y.service_secs.to_bits());
            assert!(x.slo.is_none() && x.prompt_tokens == 0, "scalar stream annotated");
            assert!(y.slo.is_some(), "tenant-mix task missing its class");
            assert!(y.prompt_tokens > 0 && y.output_tokens > 0);
        }
    }
}

#[test]
fn token_scenarios_meter_per_class_attainment_end_to_end() {
    // Token-mode oracle half 2: tenant-mix / token-drift runs actually
    // meter the per-class serving metrics, for every suite scheduler.
    for name in ["tenant-mix", "token-drift"] {
        for sched in SCHEDULERS {
            let mut cfg = small_cfg(sched);
            cfg.scenario = Scenario::by_name(name).unwrap();
            let m = run_experiment(&cfg).unwrap();
            assert!(m.token_tasks() > 0, "{sched} on {name}: no token metering");
            for k in 0..3 {
                let att = m.slo_attainment(k);
                assert!((0.0..=1.0).contains(&att), "{sched} on {name}: attainment {att}");
            }
        }
    }
}

#[test]
fn regional_failure_scenario_applies_failures_from_spec() {
    let mut cfg = small_cfg("rr");
    cfg.scenario = Scenario::by_name("regional-failure").unwrap();
    let sim = Simulation::new(cfg.clone()).unwrap();
    assert_eq!(sim.failures.len(), 3, "spec failures not resolved by the engine");
    // The failure window actually bites: some region is down at slot 3.
    let mut sim = sim;
    let seed = cfg.seed ^ topo_salt(&cfg.topology);
    let mut wl = cfg
        .scenario
        .build_workload(&cfg.workload, sim.ctx.topo.n, seed, cfg.slot_secs)
        .unwrap();
    let mut sched = torta::scheduler::build("rr", &sim.ctx, &cfg).unwrap();
    let mut metrics = torta::metrics::RunMetrics::new("rr", &cfg.topology);
    for slot in 0..4 {
        sim.step(slot, wl.as_mut(), sched.as_mut(), &mut metrics);
    }
    let down = sim.fleet.regions.iter().filter(|r| r.failed).count();
    assert_eq!(down, 3, "failure window not active");
}

#[test]
fn trace_scenario_replays_bit_identically_and_runs() {
    let dir = std::env::temp_dir().join("torta_scenario_trace");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.csv");

    let cfg = small_cfg("rr");
    let seed = cfg.seed ^ topo_salt(&cfg.topology);
    let mut gen = Diurnal::new(cfg.workload.clone(), 12, seed);
    let n = torta::workload::trace::record(&mut gen, cfg.slots, cfg.slot_secs, &path).unwrap();
    assert!(n > 0);

    // Replay through the scenario registry: stream equals the generator
    // bit-for-bit.
    let name = format!("trace:{}", path.display());
    let sc = Scenario::by_name(&name).unwrap();
    let mut replay = sc.build_workload(&cfg.workload, 12, seed, cfg.slot_secs).unwrap();
    let mut twin = Diurnal::new(cfg.workload.clone(), 12, seed);
    for slot in 0..cfg.slots {
        let want = twin.slot_tasks(slot, cfg.slot_secs);
        let got = replay.slot_tasks(slot, cfg.slot_secs);
        assert_eq!(want.len(), got.len(), "slot {slot}");
        for (w, g) in want.iter().zip(got.iter()) {
            assert_eq!(w.id, g.id);
            assert_eq!(w.arrival_secs.to_bits(), g.arrival_secs.to_bits());
            assert_eq!(w.service_secs.to_bits(), g.service_secs.to_bits());
            assert_eq!(w.deadline_secs.to_bits(), g.deadline_secs.to_bits());
            assert_eq!(w.payload_kb.to_bits(), g.payload_kb.to_bits());
        }
    }

    // And the trace scenario drives a full experiment end-to-end.
    let mut run_cfg = cfg.clone();
    run_cfg.scenario = Scenario::by_name(&name).unwrap();
    let m = run_experiment(&run_cfg).unwrap();
    assert!(m.tasks_total > 0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn custom_config_scenario_runs_end_to_end() {
    // A declarative [scenario] section (layers + failures) drives a full
    // run from config alone — the fig4-style reproducibility fix.
    let table = torta::config::Table::parse(
        r#"
        scheduler = "rr"
        slots = 8
        [torta]
        use_pjrt = false
        [scenario]
        name = "custom-smoke"
        rate_scale = 1.2
        surge = [[2, 5, 2.0, -1]]
        fail_top = [1, 3, 2]
        "#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_table(&table).unwrap();
    assert_eq!(cfg.scenario.name, "custom-smoke");
    assert_eq!(cfg.scenario.layers.len(), 2);
    assert_eq!(cfg.scenario.failures.len(), 1);
    let m = run_experiment(&cfg).unwrap();
    assert!(m.tasks_total > 0);
    assert_eq!(m.scenario, "custom-smoke");
}
