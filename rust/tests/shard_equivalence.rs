//! Shard-pipeline determinism oracle (docs/PERF.md, "Shard pipeline"):
//! the region-sharded fan-out/fan-in in `ExecutionEngine::step` and
//! TORTA's parallel micro matching must produce BIT-identical
//! `RunMetrics` and fleet end-state for every worker count — `--threads
//! 1` (the exact sequential legacy path) vs 2 vs 4 — for all four suite
//! schedulers on registry scenarios, including cross-shard migration
//! routing and a scripted stream that interleaves `Migrate` barriers
//! between `Assign` segments. Since the persistent-pool PR the fan-outs
//! run on long-lived `util::pool` workers, and the baseline schedulers
//! (rr/sdib/skylb) parallelize their autoscale + stats inner loops — the
//! dedicated cell below extends the sweep to 8 workers for them.
//!
//! Style follows `perf_equivalence.rs` / `action_equivalence.rs`: the
//! sequential path is the oracle, float comparisons are on `to_bits`.

use torta::cluster::{Fleet, ServerState};
use torta::config::ExperimentConfig;
use torta::metrics::RunMetrics;
use torta::scheduler::{empirical_alloc, Action, Ctx, PendingView, Scheduler, SlotDecision};
use torta::sim::{topo_salt, Simulation};
use torta::workload::Task;

const SCHEDULERS: [&str; 4] = ["torta", "skylb", "sdib", "rr"];
const THREADS: [usize; 3] = [1, 2, 4];

/// Fleet end-state fingerprint: every server's counters, lane backlog and
/// utilization bits, power state, model residency and chaos state (down
/// flag, health EWMA bits), in region/server order.
fn fleet_fp(fleet: &Fleet, t: f64) -> Vec<(u64, u64, u64, u64, u64, u64, u32, u64, u64)> {
    let mut fp = Vec::new();
    for shard in &fleet.regions {
        for s in &shard.servers {
            let state = match s.state {
                ServerState::Cold => 0u64,
                ServerState::Warming { .. } => 1,
                ServerState::Active => 2,
            };
            fp.push((
                s.tasks_served,
                s.model_switches,
                s.activations,
                s.backlog_secs(t).to_bits(),
                s.utilization(t).to_bits(),
                state,
                s.loaded_model.unwrap_or(u32::MAX),
                s.down as u64,
                s.health.to_bits(),
            ));
        }
    }
    fp
}

/// Bit-level fingerprint of every `RunMetrics` field the determinism
/// contract covers (floats compared on `to_bits`, i.e. exactly).
fn metrics_fp(m: &RunMetrics) -> Vec<(&'static str, u64)> {
    vec![
        ("tasks_total", m.tasks_total),
        ("tasks_dropped", m.tasks_dropped),
        ("deadline_misses", m.deadline_misses),
        ("model_switches", m.model_switches),
        ("server_activations", m.server_activations),
        ("migrations", m.migrations),
        ("migration_secs", m.migration_secs.to_bits()),
        ("response_count", m.response.len() as u64),
        ("response_mean", m.mean_response().to_bits()),
        ("waiting_mean", m.waiting.mean().to_bits()),
        ("network_mean", m.network.mean().to_bits()),
        ("power_dollars", m.power_cost_dollars.to_bits()),
        ("switching_frob", m.switching_cost_frob.to_bits()),
        ("operational", m.operational_overhead.to_bits()),
        ("lb_slots", m.lb_per_slot.len() as u64),
        ("lb_mean", m.mean_lb().to_bits()),
        // Chaos / robustness fields (docs/FAULTS.md) — all-zero on
        // chaos-free runs, bit-covered on chaos ones.
        ("task_retries", m.task_retries),
        ("lost_work_secs", m.lost_work_secs.to_bits()),
        ("recovered_tasks", m.recovered_tasks),
        ("faults_injected", m.faults_injected),
        ("quarantine_events", m.quarantine_events),
        ("server_slots", m.server_slots),
        ("server_down_slots", m.server_down_slots),
        ("ttr_count", m.ttr.len() as u64),
        ("ttr_mean", m.ttr.mean().to_bits()),
        // Token-serving fields (docs/SERVING.md) — all-zero on scalar
        // runs, bit-covered on token ones.
        ("token_tasks", m.token_tasks()),
        ("slo_i_total", m.slo_tasks_by_class[0]),
        ("slo_s_total", m.slo_tasks_by_class[1]),
        ("slo_b_total", m.slo_tasks_by_class[2]),
        ("slo_i_met", m.slo_met_by_class[0]),
        ("slo_s_met", m.slo_met_by_class[1]),
        ("slo_b_met", m.slo_met_by_class[2]),
        ("ttft_i_mean", m.ttft_by_class[0].mean().to_bits()),
        ("ttft_s_mean", m.ttft_by_class[1].mean().to_bits()),
        ("ttft_b_mean", m.ttft_by_class[2].mean().to_bits()),
        ("tpot_i_mean", m.tpot_by_class[0].mean().to_bits()),
        ("tpot_s_mean", m.tpot_by_class[1].mean().to_bits()),
        ("tpot_b_mean", m.tpot_by_class[2].mean().to_bits()),
    ]
}

fn assert_metrics_bits(a: &RunMetrics, b: &RunMetrics, label: &str) {
    for ((name, x), (_, y)) in metrics_fp(a).into_iter().zip(metrics_fp(b)) {
        assert_eq!(x, y, "{label}: {name} diverged");
    }
}

/// One full engine run with the worker count pinned; returns the metrics
/// and the fleet end-state fingerprint.
fn run_cell(
    scheduler: &str,
    scenario: &str,
    slots: usize,
    threads: usize,
) -> (RunMetrics, Vec<(u64, u64, u64, u64, u64, u64, u32, u64, u64)>) {
    let mut cfg = ExperimentConfig::default();
    cfg.scheduler = scheduler.into();
    cfg.slots = slots;
    cfg.torta.use_pjrt = false;
    cfg.torta.threads = threads;
    cfg.scenario = torta::scenario::Scenario::by_name(scenario).unwrap();
    let mut engine = Simulation::new(cfg.clone()).unwrap();
    assert_eq!(engine.threads(), threads, "explicit torta.threads must pin the count");
    let seed = cfg.seed ^ topo_salt(&engine.ctx.topo.name);
    let n = engine.ctx.topo.n;
    let mut wl = cfg
        .scenario
        .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
        .unwrap();
    let mut sched = torta::scheduler::build(&cfg.scheduler, &engine.ctx, &cfg).unwrap();
    let m = engine.run(wl.as_mut(), sched.as_mut());
    let end = slots as f64 * cfg.slot_secs;
    (m, fleet_fp(&engine.fleet, end))
}

fn assert_cell_equivalent(scheduler: &str, scenario: &str, slots: usize) -> RunMetrics {
    let (m1, f1) = run_cell(scheduler, scenario, slots, THREADS[0]);
    assert!(m1.tasks_total > 0, "{scheduler}@{scenario}: empty run proves nothing");
    for &threads in &THREADS[1..] {
        let (mt, ft) = run_cell(scheduler, scenario, slots, threads);
        let label = format!("{scheduler}@{scenario} threads={threads}");
        assert_metrics_bits(&m1, &mt, &label);
        assert_eq!(f1, ft, "{label}: fleet end state diverged");
    }
    m1
}

/// Acceptance: RunMetrics + fleet end-state bit-identical across
/// `--threads 1/2/4` for all four schedulers — registry scenario #1
/// (regional-failure exercises the failed-region sweep, rebuffering and
/// the rescue paths under sharding).
#[test]
fn bit_identical_across_thread_counts_regional_failure() {
    for scheduler in SCHEDULERS {
        assert_cell_equivalent(scheduler, "regional-failure", 14);
    }
}

/// Acceptance: same contract on registry scenario #2 (flash-crowd's
/// one-region hotspot skews the per-shard batch sizes, stressing the
/// fan-in merge order rather than balanced shards).
#[test]
fn bit_identical_across_thread_counts_flash_crowd() {
    for scheduler in SCHEDULERS {
        assert_cell_equivalent(scheduler, "flash-crowd", 26);
    }
}

/// Acceptance (persistent-pool PR): the baseline schedulers'
/// shard-parallel inner loops — the `autoscale_all` fan-out and the
/// `snapshot_stats` sweep — stay bit-identical across `--threads
/// 1/2/4/8`, including 8 workers on a 12-region topology (more workers
/// than shards; the pool clamps to the job count instead of engaging
/// idle threads).
#[test]
fn baseline_scheduler_inner_loops_bit_identical_threads_1_2_4_8() {
    for scheduler in ["rr", "sdib", "skylb"] {
        let (m1, f1) = run_cell(scheduler, "flash-crowd", 14, 1);
        assert!(m1.tasks_total > 0, "{scheduler}@flash-crowd: empty run proves nothing");
        for threads in [2usize, 4, 8] {
            let (mt, ft) = run_cell(scheduler, "flash-crowd", 14, threads);
            let label = format!("{scheduler}@flash-crowd threads={threads}");
            assert_metrics_bits(&m1, &mt, &label);
            assert_eq!(f1, ft, "{label}: fleet end state diverged");
        }
    }
}

/// Acceptance (docs/FAULTS.md): chaos runs inherit the determinism
/// contract — the fault schedule is resolved before any fan-out and all
/// chaos mutation happens in the sequential boundary sweep, so crashes,
/// retry re-queues, stragglers and quarantines are bit-identical across
/// `--threads 1/2/4`. The cell must actually observe faults, otherwise
/// the equivalence is vacuous.
#[test]
fn bit_identical_across_thread_counts_chaos_crash() {
    for scheduler in SCHEDULERS {
        let m = assert_cell_equivalent(scheduler, "chaos-crash", 16);
        assert!(m.server_slots > 0, "{scheduler}@chaos-crash: fault sweep never ran");
        assert!(m.faults_injected > 0, "{scheduler}@chaos-crash: no crash fired");
    }
}

/// Same contract on the other two chaos presets — flaky-network layers
/// link degradation (the network-seconds multiplier crosses shard
/// boundaries) and stragglers on top of crashes; brownout exercises the
/// correlated partial-region outage.
#[test]
fn bit_identical_across_thread_counts_chaos_presets() {
    let m = assert_cell_equivalent("torta", "flaky-network", 24);
    assert!(m.faults_injected > 0, "flaky-network: no fault fired");
    let m = assert_cell_equivalent("rr", "brownout", 24);
    assert!(m.faults_injected > 0, "brownout: no fault fired");
}

/// Token-serving runs (docs/SERVING.md) inherit the determinism
/// contract: slot occupancy, widened concurrency and the per-class
/// TTFT/TPOT/SLO metering are bit-identical across `--threads 1/2/4`
/// for every suite scheduler.
#[test]
fn bit_identical_across_thread_counts_token_scenarios() {
    for scheduler in SCHEDULERS {
        let m = assert_cell_equivalent(scheduler, "tenant-mix", 14);
        assert!(m.token_tasks() > 0, "{scheduler}@tenant-mix: no token metering");
    }
    // token-drift at a horizon past its ramp (at 16 + ramp 8), so the
    // drifted decode lengths are in the covered bits.
    let m = assert_cell_equivalent("torta", "token-drift", 28);
    assert!(m.token_tasks() > 0, "token-drift: no token metering");
}

/// Chaos + token: a chaos-crash run under the TokenStream model must
/// keep the fault sweep (crash harvest of partially-decoded work, retry
/// release) AND the token metering bit-identical across worker counts.
#[test]
fn bit_identical_across_thread_counts_chaos_token() {
    use torta::serving::ServingSpec;
    let run = |threads: usize| {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = "torta".into();
        cfg.slots = 16;
        cfg.torta.use_pjrt = false;
        cfg.torta.threads = threads;
        cfg.scenario = torta::scenario::Scenario::by_name("chaos-crash").unwrap();
        cfg.scenario.serving = Some(ServingSpec::default());
        let mut engine = Simulation::new(cfg.clone()).unwrap();
        let seed = cfg.seed ^ topo_salt(&engine.ctx.topo.name);
        let n = engine.ctx.topo.n;
        let mut wl = cfg
            .scenario
            .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
            .unwrap();
        let mut sched = torta::scheduler::build(&cfg.scheduler, &engine.ctx, &cfg).unwrap();
        let m = engine.run(wl.as_mut(), sched.as_mut());
        let end = cfg.slots as f64 * cfg.slot_secs;
        (m, fleet_fp(&engine.fleet, end))
    };
    let (m1, f1) = run(1);
    assert!(m1.faults_injected > 0, "chaos+token: no crash fired — cell is vacuous");
    assert!(m1.token_tasks() > 0, "chaos+token: no token metering — cell is vacuous");
    for threads in [2usize, 4] {
        let (mt, ft) = run(threads);
        let label = format!("torta@chaos-crash+token threads={threads}");
        assert_metrics_bits(&m1, &mt, &label);
        assert_eq!(f1, ft, "{label}: fleet end state diverged");
    }
}

/// Cross-shard migrations under the parallel pipeline: TORTA's
/// `emit_migrations` rescue path (failed sources, overloaded servers)
/// must route source -> dest across shard boundaries with identical
/// metering for any worker count — and the scenario must actually
/// migrate, otherwise the equivalence is vacuous.
#[test]
fn migration_rescue_bit_identical_across_thread_counts() {
    let run = |threads: usize| {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = "torta-native".into();
        cfg.slots = 14;
        cfg.workload.base_rate = 240.0;
        cfg.torta.use_pjrt = false;
        cfg.torta.migrate_backlog_secs = 1.0;
        cfg.torta.threads = threads;
        cfg.scenario = torta::scenario::Scenario::by_name("regional-failure").unwrap();
        let mut engine = Simulation::new(cfg.clone()).unwrap();
        let seed = cfg.seed ^ topo_salt(&engine.ctx.topo.name);
        let n = engine.ctx.topo.n;
        let mut wl = cfg
            .scenario
            .build_workload(&cfg.workload, n, seed, cfg.slot_secs)
            .unwrap();
        let mut sched = torta::scheduler::build(&cfg.scheduler, &engine.ctx, &cfg).unwrap();
        let m = engine.run(wl.as_mut(), sched.as_mut());
        let end = cfg.slots as f64 * cfg.slot_secs;
        (m, fleet_fp(&engine.fleet, end))
    };
    let (m1, f1) = run(1);
    assert!(
        m1.migrations >= 1,
        "failure scenario executed no migrations — the cross-shard path went untested"
    );
    for threads in [2usize, 4] {
        let (mt, ft) = run(threads);
        let label = format!("torta-native+migration threads={threads}");
        assert_metrics_bits(&m1, &mt, &label);
        assert_eq!(f1, ft, "{label}: fleet end state diverged");
    }
}

// ---------------------------------------------------------------------------
// Scripted interleaved stream: Migrate barriers between Assign segments.
// ---------------------------------------------------------------------------

/// Slot 0: pile every task onto one region-0 server (creates queued
/// reservations). Slot 1+: emit `Assign -> Migrate -> Assign -> Buffer...`
/// so the parallel engine must flush its open segment mid-stream — the
/// worst case for the segmented fan-out, impossible to reorder silently.
struct InterleavedScript {
    r: usize,
}

impl Scheduler for InterleavedScript {
    fn name(&self) -> &'static str {
        "interleave-script"
    }

    fn decide(
        &mut self,
        _ctx: &Ctx,
        fleet: &mut Fleet,
        tasks: Vec<Task>,
        pending: &[PendingView],
        slot: usize,
        now: f64,
    ) -> SlotDecision {
        let mut actions: Vec<Action> = Vec::new();
        if slot == 0 {
            let server = fleet.regions[0]
                .servers
                .iter()
                .position(|s| s.accepting(now))
                .expect("region 0 has an accepting server");
            let assignments: Vec<(Task, usize, usize)> =
                tasks.into_iter().map(|t| (t, 0usize, server)).collect();
            let alloc = empirical_alloc(&assignments, self.r);
            for (task, region, sv) in assignments {
                actions.push(Action::Assign { task, region, server: sv });
            }
            return SlotDecision { actions, alloc };
        }
        let dest = fleet.regions[1]
            .servers
            .iter()
            .position(|s| s.accepting(now))
            .expect("region 1 has an accepting server");
        let mut it = tasks.into_iter();
        if let Some(task) = it.next() {
            actions.push(Action::Assign { task, region: 1, server: dest });
        }
        if let Some(p) = pending.last() {
            actions.push(Action::Migrate {
                task_id: p.task_id,
                from: (p.region, p.server),
                to: (1, dest),
            });
        }
        if let Some(task) = it.next() {
            actions.push(Action::Assign { task, region: 1, server: dest });
        }
        for task in it {
            actions.push(Action::Buffer { task });
        }
        SlotDecision { actions, alloc: empirical_alloc(&[], self.r) }
    }
}

#[test]
fn interleaved_migrate_stream_is_barrier_safe() {
    let run = |threads: usize| {
        let mut cfg = ExperimentConfig::default();
        cfg.slots = 2;
        cfg.workload.base_rate = 10.0;
        cfg.torta.migrate_backlog_secs = 1.0; // enables pending tracking
        cfg.torta.threads = threads;
        let mut engine = Simulation::new(cfg.clone()).unwrap();
        let seed = cfg.seed ^ topo_salt(&cfg.topology);
        let n = engine.ctx.topo.n;
        let mut wl = torta::workload::DiurnalWorkload::new(cfg.workload.clone(), n, seed);
        let mut sched = InterleavedScript { r: n };
        let mut metrics = RunMetrics::new("interleave-script", &cfg.topology);
        engine.step(0, &mut wl, &mut sched, &mut metrics);
        assert!(engine.pending_len() >= 1, "slot 0 must leave queued reservations");
        engine.step(1, &mut wl, &mut sched, &mut metrics);
        // Results carry every executed action in stream order; the Debug
        // rendering round-trips floats, so string equality is bit
        // equality.
        let results_dbg = format!("{:?}", engine.last_outcome().unwrap().results);
        let backlog = engine.backlog_len();
        let pending = engine.pending_len();
        engine.finish(&mut metrics);
        let end = 2.0 * cfg.slot_secs;
        (results_dbg, backlog, pending, metrics, fleet_fp(&engine.fleet, end))
    };
    let (r1, b1, p1, m1, f1) = run(1);
    assert!(
        r1.contains("Migrated"),
        "the scripted cross-shard migration must execute: {r1}"
    );
    for threads in [2usize, 4] {
        let (rt, bt, pt, mt, ft) = run(threads);
        let label = format!("interleaved threads={threads}");
        assert_eq!(r1, rt, "{label}: per-action results diverged");
        assert_eq!(b1, bt, "{label}: backlog depth diverged");
        assert_eq!(p1, pt, "{label}: pending depth diverged");
        assert_metrics_bits(&m1, &mt, &label);
        assert_eq!(f1, ft, "{label}: fleet end state diverged");
    }
}
