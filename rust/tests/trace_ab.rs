//! Integration: trace record/replay gives byte-identical workloads for
//! A/B scheduler comparisons, and replay drives the full engine.

use torta::config::ExperimentConfig;
use torta::metrics::RunMetrics;
use torta::sim::Simulation;
use torta::workload::trace::{record, TraceWorkload};
use torta::workload::{DiurnalWorkload, WorkloadSource};

#[test]
fn same_trace_two_schedulers_identical_task_sets() {
    let dir = std::env::temp_dir().join("torta_trace_ab");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ab.csv");

    let mut cfg = ExperimentConfig::default();
    cfg.slots = 12;
    cfg.torta.use_pjrt = false;

    let mut gen = DiurnalWorkload::new(cfg.workload.clone(), 12, 7);
    let n = record(&mut gen, cfg.slots, cfg.slot_secs, &path).unwrap();
    assert!(n > 0);

    let mut results = Vec::new();
    for sched in ["torta-native", "rr"] {
        let mut c = cfg.clone();
        c.scheduler = sched.into();
        let mut sim = Simulation::new(c.clone()).unwrap();
        let mut wl = TraceWorkload::load(&path, 12).unwrap();
        let mut s = torta::scheduler::build(sched, &sim.ctx, &c).unwrap();
        let mut m = RunMetrics::new(sched, "abilene");
        for slot in 0..c.slots {
            sim.step(slot, &mut wl, s.as_mut(), &mut m);
        }
        results.push((m.tasks_total + sim.backlog_len() as u64, m.mean_response()));
    }
    // Both schedulers saw exactly the recorded tasks.
    assert_eq!(results[0].0, n as u64);
    assert_eq!(results[1].0, n as u64);
    // And produced different quality (not byte-equal accounting).
    assert_ne!(results[0].1, results[1].1);
    std::fs::remove_file(&path).ok();
}

#[test]
fn replay_is_deterministic() {
    let dir = std::env::temp_dir().join("torta_trace_det");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("det.csv");
    let cfg = ExperimentConfig::default();
    let mut gen = DiurnalWorkload::new(cfg.workload.clone(), 12, 11);
    record(&mut gen, 6, 45.0, &path).unwrap();

    let collect = || {
        let mut wl = TraceWorkload::load(&path, 12).unwrap();
        let mut ids = Vec::new();
        for slot in 0..6 {
            for t in wl.slot_tasks(slot, 45.0) {
                ids.push(t.id);
            }
        }
        ids
    };
    assert_eq!(collect(), collect());
    std::fs::remove_file(&path).ok();
}
