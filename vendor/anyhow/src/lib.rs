//! Vendored, dependency-free stand-in for the `anyhow` crate.
//!
//! The build environment for this repository is fully offline, so external
//! crates cannot be fetched; this path dependency provides the (small)
//! subset of the anyhow API the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the [`Context`]
//! extension trait. Swapping back to the real crate is a one-line change
//! in the root `Cargo.toml`; no source edits are required.

use std::fmt;

/// A catch-all error: an optional chain of human context strings wrapped
/// around an optional underlying `std::error::Error`.
pub struct Error {
    /// Outermost context first.
    context: Vec<String>,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message (what `anyhow!` expands
    /// to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { context: vec![message.to_string()], source: None }
    }

    /// Prepend a context layer (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.insert(0, context.to_string());
        self
    }

    /// Borrow the underlying source error, if any.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_ref().map(|e| e.as_ref() as &(dyn std::error::Error + 'static))
    }

    /// Downcast the underlying source error to a concrete type (the
    /// subset of anyhow's downcasting the workspace uses: `?`-converted
    /// errors keep their concrete type in `source`).
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        self.source.as_ref().and_then(|s| s.as_ref().downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for c in &self.context {
            if !first {
                write!(f, ": ")?;
            }
            write!(f, "{c}")?;
            first = false;
        }
        if let Some(src) = &self.source {
            if !first {
                write!(f, ": ")?;
            }
            write!(f, "{src}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent (`?` works on any std error type).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { context: Vec::new(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*).into())
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            ))
            .into());
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn downcast_ref_recovers_concrete_type() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("io error downcast");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        assert!(anyhow!("plain message").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_layers_prepend() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: gone");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative input -2");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
        let owned = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
    }

    #[test]
    fn ensure_without_message_names_condition() {
        fn f() -> Result<()> {
            let n = 1;
            ensure!(n > 5);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("n > 5"));
    }
}
